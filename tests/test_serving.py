"""Serving tier: PFCS paged KV cache, expert cache, engine end-to-end."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.serving.expert_cache import ExpertCache
from repro.serving.kv_cache import PagedKVCache


def test_prefix_sharing_is_content_addressed():
    kv = PagedKVCache(hbm_pages=64, page_size=4)
    a = kv.register_request(1, [1, 2, 3, 4, 5, 6, 7, 8])
    b = kv.register_request(2, [1, 2, 3, 4, 9, 9, 9, 9])
    assert a[0] == b[0]          # identical first block -> same page
    assert a[1] != b[1]


def test_shared_prefix_via_gcd_exact():
    kv = PagedKVCache(hbm_pages=64, page_size=4)
    kv.register_request(1, list(range(16)))
    kv.register_request(2, list(range(8)) + [99, 98, 97, 96])
    shared = kv.shared_prefix(1, 2)
    # exactly the two pages covering tokens 0..7 — no false sharing
    assert len(shared) == 2
    kv.register_request(3, [55] * 16)
    assert kv.shared_prefix(1, 3) == []


def test_page_prefetch_follows_chain():
    kv = PagedKVCache(hbm_pages=8, page_size=4, prefetch_budget=4)
    pages = kv.register_request(1, list(range(32)))   # 8-page chain
    kv.touch(1, 0)
    # successor of page 0 must now be HBM-resident (prefetched)
    assert pages[1] in kv.hbm
    assert kv.stats.prefetches >= 1


def test_eviction_to_host_and_demand_return():
    kv = PagedKVCache(hbm_pages=2, page_size=4, prefetch_budget=0)
    kv.register_request(1, list(range(24)))           # 6 pages
    for i in range(6):
        kv.touch(1, i)
    assert len(kv.hbm) <= 2
    assert kv.stats.evictions > 0
    tier = kv.touch(1, 0)                             # long-evicted page
    assert tier == "host"


def test_expert_cache_prefetch_beats_no_prefetch():
    """With structured co-activation, PFCS prefetch lifts the HBM hit rate
    vs an identical cache without relationship knowledge."""
    rng = np.random.default_rng(0)
    E, slots = 64, 16
    groups = [tuple(rng.choice(E, size=8, replace=False)) for _ in range(6)]

    def run(prefetch_budget):
        ec = ExpertCache(E, hbm_slots=slots, prefetch_budget=prefetch_budget)
        for g in groups:
            ec.observe_routing([g])
        for _ in range(300):
            g = groups[int(rng.integers(len(groups)))]
            # activation arrives expert-by-expert (the all-to-all schedule)
            ec.activate([g[0]])
            ec.activate(list(g[1:]))
        return ec.stats.hit_rate

    rng = np.random.default_rng(0)
    with_pf = run(prefetch_budget=7)
    rng = np.random.default_rng(0)
    without = run(prefetch_budget=0)
    assert with_pf > without


def test_engine_end_to_end_smoke():
    from repro.configs import get_smoke
    from repro.models import build_model
    from repro.serving.engine import ServingEngine

    cfg = get_smoke("qwen2.5-3b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, max_batch=2, max_seq=96, page_size=8)
    shared = list(range(16))          # two full shared pages
    for i in range(3):
        eng.submit(shared + [20 + i], max_new_tokens=4)
    done = eng.run_until_idle()
    assert len(done) == 3
    assert all(len(r.generated) == 4 for r in done)
    assert eng.pages.stats.shared_prefix_pages > 0
