"""Serving tier: PFCS paged KV cache, expert cache, engine end-to-end.

Parity discipline (mirrors tests/test_engine.py): the scalar
``PagedKVCache`` is the bit-exact oracle; ``VectorizedPagedKVCache``
must reproduce every ``PARITY_COUNTERS`` field, every per-touch tier,
and the exact HBM LRU order under any interleaving of registration and
touches — including HBM-slot exhaustion/eviction edges and the gcd
shared-prefix path.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from strategies import apply_kv_ops, drive_kv
from repro.serving.expert_cache import ExpertCache
from repro.serving.kv_cache import PARITY_COUNTERS, PagedKVCache
from repro.serving.kv_cache_sharded import ShardedPagedKVCache
from repro.serving.kv_cache_vec import VectorizedPagedKVCache

IMPLS = {
    "scalar": PagedKVCache,
    "vec": VectorizedPagedKVCache,
    "sharded": ShardedPagedKVCache,
}


def _mk(impl: str, **kw):
    return IMPLS[impl](**kw)


# --------------------------------------------------------------------------- #
# single-implementation behavior (both backends must satisfy it)              #
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("impl", list(IMPLS))
def test_prefix_sharing_is_content_addressed(impl):
    kv = _mk(impl, hbm_pages=64, page_size=4)
    a = kv.register_request(1, [1, 2, 3, 4, 5, 6, 7, 8])
    b = kv.register_request(2, [1, 2, 3, 4, 9, 9, 9, 9])
    assert a[0] == b[0]          # identical first block -> same page
    assert a[1] != b[1]


@pytest.mark.parametrize("impl", list(IMPLS))
def test_shared_prefix_via_gcd_exact(impl):
    kv = _mk(impl, hbm_pages=64, page_size=4)
    kv.register_request(1, list(range(16)))
    kv.register_request(2, list(range(8)) + [99, 98, 97, 96])
    shared = kv.shared_prefix(1, 2)
    # exactly the two pages covering tokens 0..7 — no false sharing
    assert len(shared) == 2
    kv.register_request(3, [55] * 16)
    assert kv.shared_prefix(1, 3) == []


@pytest.mark.parametrize("impl", list(IMPLS))
def test_page_prefetch_follows_chain(impl):
    kv = _mk(impl, hbm_pages=8, page_size=4, prefetch_budget=4)
    pages = kv.register_request(1, list(range(32)))   # 8-page chain
    kv.touch(1, 0)
    # successor of page 0 must now be HBM-resident (prefetched)
    assert pages[1] in kv.hbm
    assert kv.stats.prefetches >= 1


@pytest.mark.parametrize("impl", list(IMPLS))
def test_eviction_to_host_and_demand_return(impl):
    kv = _mk(impl, hbm_pages=2, page_size=4, prefetch_budget=0)
    kv.register_request(1, list(range(24)))           # 6 pages
    for i in range(6):
        kv.touch(1, i)
    assert len(kv.hbm) <= 2
    assert kv.stats.evictions > 0
    assert kv.stats.prefetches == 0                   # budget 0: disabled
    tier = kv.touch(1, 0)                             # long-evicted page
    assert tier == "host"


# --------------------------------------------------------------------------- #
# vec == scalar, bit for bit                                                  #
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("hbm,budget", [(16, 4), (2, 0), (64, 8), (4, 1),
                                        (1, 2)])
def test_vec_matches_scalar_oracle(hbm, budget):
    """Deterministic randomized workload (``strategies.drive_kv``):
    shared-prefix request mix, interleaved registration and touches,
    releases."""
    for seed in (0, 1, 2):
        a = PagedKVCache(hbm_pages=hbm, page_size=4, prefetch_budget=budget)
        b = VectorizedPagedKVCache(hbm_pages=hbm, page_size=4,
                                   prefetch_budget=budget)
        ta, tb = drive_kv(a, seed), drive_kv(b, seed)
        assert ta == tb                              # per-touch tiers
        for f in PARITY_COUNTERS:
            assert getattr(a.stats, f) == getattr(b.stats, f), f
        assert list(a.hbm.items()) == list(b.hbm.items())   # exact LRU order
        assert a.host == b.host
    # the scalar oracle scans the registry per touched page (when
    # prefetch is on); the vectorized cache must never scan on the
    # touch path
    if budget > 0:
        assert a.stats.registry_scans > 0
    assert b.stats.registry_scans == 0


def test_touch_batch_equals_sequential_touches():
    a = VectorizedPagedKVCache(hbm_pages=8, page_size=4, prefetch_budget=2)
    b = VectorizedPagedKVCache(hbm_pages=8, page_size=4, prefetch_budget=2)
    for kv in (a, b):
        kv.register_request(0, list(range(32)))
        kv.register_request(1, list(range(16)) + [77] * 16)
    items = [(0, 5), (1, 7), (0, 0), (1, 0), (0, 7), (0, 5)]
    bulk = a.touch_batch(items)
    seq = [b.touch(r, i) for r, i in items]
    assert bulk == seq
    assert a.stats.parity_tuple() == b.stats.parity_tuple()
    assert list(a.hbm.items()) == list(b.hbm.items())


def test_hbm_slot_exhaustion_single_slot():
    """Degenerate 1-slot HBM: every insert evicts, counters still match."""
    a = PagedKVCache(hbm_pages=1, page_size=4, prefetch_budget=3)
    b = VectorizedPagedKVCache(hbm_pages=1, page_size=4, prefetch_budget=3)
    for kv in (a, b):
        kv.register_request(0, list(range(40)))       # 10 pages
        for i in list(range(10)) + [0, 9, 5]:
            kv.touch(0, i)
    assert a.stats.parity_tuple() == b.stats.parity_tuple()
    assert list(a.hbm.items()) == list(b.hbm.items())
    assert a.stats.evictions > 0


def test_out_of_band_registry_drop_forces_rebuild():
    """An out-of-band registry mutation (Algorithm-1 prime recycling via
    ``assigner.release`` drops relationships) must not be masked by the
    incremental table maintenance: the next touch rebuilds in bulk and
    parity with the oracle holds.  The drop rides the chaos-event
    machinery (``strategies.apply_kv_ops`` schedule) so the same event
    stream also drives the elastic fuzz in tests/test_elastic.py."""
    ops = [("register", 0, tuple(range(16))),          # pages 0..3
           ("register", 1, tuple(list(range(8)) + [9] * 8)),
           ("touch", 0, 0), ("touch", 0, 2)]
    schedule = {1: [("drop", 1)]}                      # drop page 1's prime
    a = PagedKVCache(hbm_pages=8, page_size=4, prefetch_budget=2)
    b = VectorizedPagedKVCache(hbm_pages=8, page_size=4, prefetch_budget=2)
    tiers = {kv: apply_kv_ops(kv, ops, schedule=schedule) for kv in (a, b)}
    assert tiers[a] == tiers[b]
    assert a.stats.parity_tuple() == b.stats.parity_tuple()
    assert list(a.hbm.items()) == list(b.hbm.items())


def test_prime_pool_exhaustion_recycling_parity():
    """Drive BOTH caches into Algorithm-1 prime recycling (the
    ``recycle_fraction`` path) under long-horizon churn: tiny custom
    pools exhaust, hot upcoming pages take the recycle branch, recycled
    primes get reassigned — and the vectorized cache must stay bit-exact
    on PARITY_COUNTERS, per-touch tiers, LRU order, the prefetch log,
    AND gcd shared-prefix answers (the stale-chunk class of divergence
    regression-tested in tests/test_tenancy.py)."""
    from repro.core.assignment import PrimeAssigner
    from repro.core.primes import CacheLevel, HierarchicalPrimeAllocator

    ranges = {CacheLevel.L1: (2, 13), CacheLevel.L2: (17, 97),
              CacheLevel.L3: (101, 199), CacheLevel.MEM: (211, None)}

    def run(cls):
        kv = cls(hbm_pages=8, page_size=4, prefetch_budget=2)
        # shrink the prime space so churn actually exhausts it (no page
        # registered yet: identity state swaps cleanly)
        kv.assigner = PrimeAssigner(HierarchicalPrimeAllocator(ranges),
                                    kv.registry)
        tiers = []
        for r in range(40):
            # mark the upcoming pages hot (recycle needs freq > 0.3,
            # i.e. two EWMA records) — identical calls on both caches
            for k in range(6):
                kv.assigner.tracker.record(kv._next_page + k)
                kv.assigner.tracker.record(kv._next_page + k)
            kv.register_request(r, [r * 40 + k for k in range(16)])
            tiers.extend(kv.touch_batch(
                [(r, j) for j in range(len(kv.chains[r]))]))
            if r >= 8 and r % 3 == 0:
                kv.release_request(r - 8)
        return kv, tiers

    a, ta = run(PagedKVCache)
    b, tb = run(VectorizedPagedKVCache)
    # churn genuinely took the recycle path, identically
    assert a.assigner.stats.recycle_events > 0
    assert (a.assigner.stats.recycle_events
            == b.assigner.stats.recycle_events)
    assert (a.assigner.stats.recycled_primes
            == b.assigner.stats.recycled_primes)
    assert ta == tb
    for f in PARITY_COUNTERS:
        assert getattr(a.stats, f) == getattr(b.stats, f), f
    assert list(a.hbm.items()) == list(b.hbm.items())
    assert a.host == b.host
    assert a.prefetch_log == b.prefetch_log
    # gcd shared-prefix answers agree even with recycled+reused primes
    live = [r for r in a.chains if r in b.chains][-6:]
    for i in live:
        for j in live:
            if i < j:
                assert a.shared_prefix(i, j) == b.shared_prefix(i, j), (i, j)


def test_vec_rejects_bad_config():
    with pytest.raises(ValueError):
        VectorizedPagedKVCache(hbm_pages=0)
    with pytest.raises(ValueError):
        VectorizedPagedKVCache(discover="magic")


# --------------------------------------------------------------------------- #
# discovery tables: incremental == bulk host == bulk Pallas kernels           #
# --------------------------------------------------------------------------- #

def test_successor_table_backends_agree():
    from repro.core.engine import successor_table

    kv = VectorizedPagedKVCache(hbm_pages=16, page_size=4,
                                prefetch_budget=3)
    rng = np.random.default_rng(5)
    shared = list(rng.integers(0, 200, size=16))
    for r in range(8):
        tail = list(rng.integers(0, 200, size=int(rng.integers(4, 16))))
        kv.register_request(r, shared[:int(rng.integers(0, 16))] + tail)

    inc = kv.successor_rows()
    pages = range(kv._next_page)
    host = {k: v for k, v in successor_table(
        kv.registry, kv.assigner, pages, discover="host").items() if v}
    kern = {k: v for k, v in successor_table(
        kv.registry, kv.assigner, pages, discover="kernel").items() if v}
    assert inc == host == kern
    # a bulk kernel refresh reproduces the incrementally-maintained table
    kv.refresh_tables(discover="kernel")
    assert kv.successor_rows() == inc
    assert kv.bulk_refreshes == 1


def test_shared_prefix_gcd_kernel_parity():
    """The vectorized cache recovers shared prefixes through the batched
    gcd kernel over chunked chain composites — identical to the scalar
    arbitrary-precision gcd."""
    a = PagedKVCache(hbm_pages=64, page_size=4)
    b = VectorizedPagedKVCache(hbm_pages=64, page_size=4)
    rng = np.random.default_rng(9)
    shared = list(rng.integers(0, 300, size=24))
    for kv in (a, b):
        rng2 = np.random.default_rng(9)
        for r in range(6):
            pfx = int(rng2.integers(0, 24))
            tail = list(rng2.integers(300, 600,
                                      size=int(rng2.integers(4, 30))))
            kv.register_request(r, shared[:pfx] + tail)
    pairs = [(i, j) for i in range(6) for j in range(i + 1, 6)]
    for i, j in pairs:
        assert a.shared_prefix(i, j) == b.shared_prefix(i, j), (i, j)
    # bulk path: every pair through ONE gcd_batch call
    bulk = b.shared_prefix_bulk(pairs)
    for p in pairs:
        assert bulk[p] == a.shared_prefix(*p), p


# --------------------------------------------------------------------------- #
# expert cache                                                                #
# --------------------------------------------------------------------------- #

def test_expert_cache_prefetch_beats_no_prefetch():
    """With structured co-activation, PFCS prefetch lifts the HBM hit rate
    vs an identical cache without relationship knowledge.  The workload
    comes from the shared expert-strategy builder (the same spec family
    the differential fuzz in tests/test_serving_moe.py draws from)."""
    from strategies import ExpertWorkloadSpec, build_expert_sets

    spec = ExpertWorkloadSpec(seed=0, n_experts=64, n_steps=150, batch=2,
                              group_size=8, n_groups=6)
    batches = build_expert_sets(spec)

    def run(prefetch_budget):
        ec = ExpertCache(spec.n_experts, hbm_slots=16,
                         prefetch_budget=prefetch_budget)
        for batch in batches:
            ec.observe_routing(batch)
            # activation arrives expert-by-expert (the all-to-all
            # schedule): head first, then the co-fired tail
            for g in batch:
                ec.activate([g[0]])
                ec.activate(list(g[1:]))
        return ec.stats.hit_rate

    assert run(prefetch_budget=7) > run(prefetch_budget=0)


# --------------------------------------------------------------------------- #
# serving engine                                                              #
# --------------------------------------------------------------------------- #

def test_engine_end_to_end_smoke():
    from repro.configs import get_smoke
    from repro.models import build_model
    from repro.serving.engine import ServingEngine

    cfg = get_smoke("qwen2.5-3b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, max_batch=2, max_seq=96, page_size=8)
    shared = list(range(16))          # two full shared pages
    for i in range(3):
        eng.submit(shared + [20 + i], max_new_tokens=4)
    done = eng.run_until_idle()
    assert len(done) == 3
    assert all(len(r.generated) == 4 for r in done)
    assert eng.pages.stats.shared_prefix_pages > 0


def _engine_workload(eng, n_req=160, seed=0):
    rng = np.random.default_rng(seed)
    shared = list(rng.integers(0, 5000, size=48))
    for r in range(n_req):
        tail = list(rng.integers(0, 5000, size=int(rng.integers(8, 40))))
        eng.submit(shared[:int(rng.integers(0, 48))] + tail,
                   max_new_tokens=6)
    return eng.run_until_idle()


def test_engine_vec_scalar_parity():
    """Null-model engines over either cache backend produce identical
    tokens AND identical page counters on the same workload."""
    from repro.serving.engine import ServingEngine

    engines = {kv: ServingEngine(None, None, max_batch=16, page_size=8,
                                 hbm_pages=32, kv=kv, reread_window=2)
               for kv in ("vec", "scalar")}
    done = {kv: _engine_workload(e, n_req=48) for kv, e in engines.items()}
    gen = {kv: [(r.req_id, tuple(r.generated)) for r in sorted(
        ds, key=lambda r: r.req_id)] for kv, ds in done.items()}
    assert gen["vec"] == gen["scalar"]
    assert (engines["vec"].pages.stats.parity_tuple()
            == engines["scalar"].pages.stats.parity_tuple())
    assert engines["vec"].pages.stats.registry_scans == 0
    assert engines["scalar"].pages.stats.registry_scans > 0


def test_engine_sustains_hundred_plus_concurrency():
    """The vectorized cache lets one engine tick drive 100+ concurrent
    requests with zero per-page discovery scans (the load benchmark's
    acceptance gate, at test scale)."""
    from repro.serving.engine import ServingEngine

    eng = ServingEngine(None, None, max_batch=128, page_size=16,
                        hbm_pages=96, kv="vec", reread_window=2)
    done = _engine_workload(eng, n_req=192)
    assert len(done) == 192
    assert eng.peak_live >= 100
    assert eng.pages.stats.registry_scans == 0
    st = eng.pages.stats
    assert st.hbm_hits + st.host_hits + st.misses > 0
