"""Optional-dependency shim for ``hypothesis`` (see requirements-dev.txt).

The tier-1 suite must collect and run green without optional dev
dependencies.  Importing this module instead of ``hypothesis`` directly
keeps property-based tests as clean SKIPs — rather than collection
errors — when the package is absent: ``given`` degrades to a decorator
that skips at call time, ``settings`` to identity, and ``st`` to a stub
whose strategy constructors return inert placeholders (they are only
ever evaluated inside decorator argument lists).
"""

from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                     # optional dev dependency absent
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            # deliberately NOT functools.wraps: pytest must see a
            # zero-argument signature, or it treats the hypothesis
            # strategy parameters as fixtures and errors at setup
            def skipper():
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _StrategyStub:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
