"""The observability layer (DESIGN.md §13): inertness, twin trace
parity, and the export pipeline.

Three contracts are pinned here:

  * **Inertness** — attaching observability (``obs=None`` vs a
    zero-capacity tracer vs a live tracer+telemetry) changes NOTHING
    the differential suites compare: ``PARITY_COUNTERS``, tier logs,
    exact HBM LRU order, host sets, prefetch logs, and per-request
    token streams are bit-identical across all three configurations
    for every backend combination.
  * **Twin trace parity** — the scalar :class:`SlotOracle` and the
    vectorized :class:`SlotMachine` emit bit-identical event streams
    (same kinds, same lanes, same ORDER) for the same arrival trace:
    the trace is a differential axis one level finer than the
    counters, and a pinned golden run locks the schema itself.
  * **Export pipeline** — ``Observability.export_json`` round-trips
    through ``tools/trace_view.py`` into Chrome ``trace_event`` JSON
    (instant + counter + complete events under ``traceEvents``).
"""

import json
import sys
from pathlib import Path

import numpy as np
import pytest

from strategies import ArrivalSpec, build_poisson_arrivals, drive_slots
from repro.obs import (EV_ADMIT, EV_COMPLETE, EV_EVICT, EV_GCD_EXCHANGE,
                       EV_PREFETCH, EVENT_FIELDS, Observability, attach,
                       profile, trace_diff)
from repro.obs.telemetry import Progress, StreamingHist, Telemetry
from repro.obs.trace import EventTracer, TraceEvent
from repro.serving.kv_cache import PARITY_COUNTERS
from repro.serving.slots import SlotMachine, SlotOracle

TOOLS = Path(__file__).resolve().parents[1] / "tools"
sys.path.insert(0, str(TOOLS))

from trace_view import convert


# --------------------------------------------------------------------------- #
# event ring unit behavior                                                    #
# --------------------------------------------------------------------------- #

def test_ring_records_all_lanes_and_defaults():
    tr = EventTracer(capacity=8)
    tr.emit(EV_ADMIT, tick=3, slot=1, req=7)
    tr.emit(EV_EVICT, page=42, tenant=2)
    assert len(tr) == 2 and tr.total == 2 and tr.dropped == 0
    ev = tr.events()
    assert ev[0] == TraceEvent(EV_ADMIT, 3, 1, 7, -1, -1, -1, -1)
    assert ev[1].page == 42 and ev[1].tenant == 2 and ev[1].tick == -1
    assert ev[0].name == "admit" and ev[1].name == "evict"
    assert tr.as_array().shape == (2, len(EVENT_FIELDS))


def test_ring_wraparound_keeps_newest_and_counts_dropped():
    tr = EventTracer(capacity=4)
    for i in range(11):
        tr.emit(EV_ADMIT, req=i)
    assert tr.total == 11 and len(tr) == 4 and tr.dropped == 7
    assert [e.req for e in tr.events()] == [7, 8, 9, 10]   # oldest first
    tr.clear()
    assert tr.total == 0 and len(tr) == 0


def test_zero_capacity_ring_is_a_pure_counter():
    tr = EventTracer(capacity=0)
    for i in range(5):
        tr.emit(EV_PREFETCH, page=i)
    assert tr.total == 5 and len(tr) == 0 and tr.dropped == 5
    assert tr.events() == [] and tr.as_array().shape == (0, 8)


def test_trace_diff_axes():
    a, b = EventTracer(16), EventTracer(16)
    for t in (a, b):
        t.emit(EV_ADMIT, slot=0, req=1)
    assert trace_diff(a, b) is None
    b.emit(EV_EVICT, page=9)                   # b is longer
    i, ea, eb = trace_diff(a, b)
    assert i == 1 and ea is None and eb.kind == EV_EVICT
    a.emit(EV_EVICT, page=8)                   # same kind, lane differs
    i, ea, eb = trace_diff(a, b)
    assert i == 1 and ea.page == 8 and eb.page == 9
    # equal retained rows but different totals (wrapped history) differ
    c, d = EventTracer(1), EventTracer(1)
    c.emit(EV_ADMIT)
    d.emit(EV_EVICT)
    d.emit(EV_ADMIT)
    assert trace_diff(c, d) == (1, None, None)


# --------------------------------------------------------------------------- #
# telemetry / histograms / progress                                           #
# --------------------------------------------------------------------------- #

def test_streaming_hist_exact_accumulators_and_quantiles():
    h = StreamingHist()
    for v in [0, 1, 1, 2, 3, 7, 8, 100]:
        h.add(v)
    s = h.summary()
    assert s["count"] == 8 and s["sum"] == 122
    assert s["min"] == 0 and s["max"] == 100
    assert h.quantile(0.0) <= h.quantile(0.5) <= h.quantile(1.0)
    assert h.quantile(1.0) >= 100               # upper-bound estimate
    assert s["buckets"]["0"] == 1               # the zero bucket
    h2 = StreamingHist()
    assert h2.quantile(0.5) == 0 and h2.summary()["count"] == 0


def test_telemetry_gauge_rings_are_bounded():
    t = Telemetry(capacity=4)
    for i in range(10):
        t.gauge("x", i, tick=i)
    assert t.gauges["x"] == [[i, float(i)] for i in range(6, 10)]
    t.observe("lat", 5)
    exp = t.export()
    assert exp["hists"]["lat"]["count"] == 1
    assert exp["gauges"]["x"][0] == [6, 6.0]


def test_progress_quiet_suppresses_output(capsys):
    p = Progress(100, label="build", quiet=True, interval_s=0.0)
    for _ in range(100):
        p.advance()
    rep = p.finish()
    assert capsys.readouterr().err == ""
    assert rep["n"] == 100 and rep["per_s"] > 0 and p.rate > 0


def test_progress_prints_throttled_lines(capsys):
    p = Progress(50, label="reg", quiet=False, interval_s=0.0,
                 stream=sys.stderr)
    for _ in range(50):
        p.advance()
    p.finish()
    err = capsys.readouterr().err
    assert "reg" in err and "50/50" in err and "/s" in err


# --------------------------------------------------------------------------- #
# kernel profiling ledger                                                     #
# --------------------------------------------------------------------------- #

def test_kernel_scope_disabled_leaves_no_ledger():
    profile.reset()
    assert not profile.enabled()
    with profile.kernel_scope("noop", items=3):
        pass
    assert profile.summary() == {}


def test_profiling_context_accumulates_and_restores():
    profile.reset()
    with profile.profiling():
        assert profile.enabled()
        for _ in range(2):
            with profile.kernel_scope("k", items=5):
                pass
    assert not profile.enabled()
    rec = profile.summary()["k"]
    assert rec["calls"] == 2 and rec["items"] == 10
    assert rec["wall_s"] >= 0.0
    profile.reset()
    assert profile.summary() == {}


def test_kernel_wrappers_feed_the_ledger():
    from repro.kernels.ops import divisibility_scan, gcd_batch

    profile.reset()
    with profile.profiling():
        divisibility_scan([6, 10, 15], [2, 3, 5])
        gcd_batch([12, 18], [8, 27])
    led = profile.summary()
    assert led["divisibility_scan"]["calls"] == 1
    assert led["divisibility_scan"]["items"] == 3
    assert led["gcd_batch"]["items"] == 2


# --------------------------------------------------------------------------- #
# inertness: attaching obs never perturbs placement                           #
# --------------------------------------------------------------------------- #

SPEC = ArrivalSpec(seed=11, n_requests=18, rate=1.6, burst_frac=0.2,
                   max_prompt=22, max_new=8, shared_pool=12)
CFG = dict(max_batch=4, page_size=4, hbm_pages=24, prefetch_budget=2,
           reread_window=2, prefill_tokens=12, preempt_wait=3)

BACKENDS = [
    ("vec", False), ("scalar", False), ("sharded", False),
    ("elastic", False), ("vec", True), ("scalar", True),
]


def _drive(cls, kv, dedup, obs):
    # dedup rides the tenant namespace (engine factory contract)
    eng = cls(kv=kv, dedup=dedup, tenants=2 if dedup else None, obs=obs,
              **CFG)
    drive_slots(eng, build_poisson_arrivals(SPEC))
    return eng


def _placement_state(eng):
    return (
        tuple(getattr(eng.pages.stats, f) for f in PARITY_COUNTERS),
        tuple(eng.tier_log),
        tuple(eng.pages.hbm.items()),
        frozenset(eng.pages.host),
        tuple(eng.pages.prefetch_log),
        tuple(tuple(r.generated) for r in eng.requests),
        tuple((r.first_tick, r.done_tick, r.preemptions)
              for r in eng.requests),
    )


@pytest.mark.parametrize("cls", [SlotMachine, SlotOracle])
@pytest.mark.parametrize("kv,dedup", BACKENDS)
def test_tracing_off_parity_sweep(cls, kv, dedup):
    """obs=None, a zero-capacity tracer, and a live tracer+telemetry
    all produce byte-identical placement — the inertness contract."""
    base = _placement_state(_drive(cls, kv, dedup, None))
    zero = Observability(trace_capacity=0, telemetry=False)
    live = Observability(trace_capacity=4096)
    assert _placement_state(_drive(cls, kv, dedup, zero)) == base
    eng = _drive(cls, kv, dedup, live)
    assert _placement_state(eng) == base
    # the live run actually observed something
    assert live.trace.total > 0
    assert live.telemetry.ticks_seen == eng.ticks
    # and the zero-capacity tracer counted the same emissions
    assert zero.trace.total == live.trace.total


# --------------------------------------------------------------------------- #
# twin trace parity + the pinned golden run                                   #
# --------------------------------------------------------------------------- #

GOLDEN_SPEC = ArrivalSpec(seed=5, n_requests=10, rate=1.2, max_prompt=16,
                          max_new=6, shared_pool=8)


def _traced(cls, kv="vec"):
    obs = Observability(trace_capacity=8192)
    eng = cls(kv=kv, obs=obs, **CFG)
    drive_slots(eng, build_poisson_arrivals(GOLDEN_SPEC))
    return eng, obs


@pytest.mark.parametrize("kv", ["vec", "scalar"])
def test_twin_event_streams_bit_identical(kv):
    _, mo = _traced(SlotMachine, kv)
    _, oo = _traced(SlotOracle, kv)
    assert trace_diff(mo.trace, oo.trace) is None


def test_golden_trace_structure():
    """Structural pins on the golden run: every request admitted once
    and completed once, in tick order, with prefill chunks covering
    each prompt before its completion."""
    eng, obs = _traced(SlotMachine)
    evs = obs.trace.events()
    admits = [e for e in evs if e.name == "admit"]
    completes = [e for e in evs if e.name == "complete"]
    # a preempted request is re-admitted on resume: admits per request
    # = 1 + its preemption count; completes are exactly one each
    assert {e.req for e in admits} == set(range(10))
    for r in eng.requests:
        assert sum(1 for e in admits if e.req == r.req_id) \
            == 1 + r.preemptions
    assert sorted(e.req for e in completes) == list(range(10))
    ticks = [e.tick for e in evs if e.tick >= 0]
    assert ticks == sorted(ticks)               # stream is tick-ordered
    assert all(e.slot >= 0 for e in admits + completes)
    # admit precedes complete per request
    first_admit = {e.req: i for i, e in reversed(list(enumerate(evs)))
                   if e.name == "admit"}
    for i, e in enumerate(evs):
        if e.name == "complete":
            assert first_admit[e.req] < i
    # prefetch/evict events carry page attribution only
    for e in evs:
        if e.name in ("prefetch", "evict"):
            assert e.page >= 0 and e.slot == -1


def test_golden_trace_pinned_prefix():
    """The exact head of the golden machine trace — pins the event
    schema and emission order (regenerate deliberately if the serving
    semantics change)."""
    _, obs = _traced(SlotMachine)
    head = [(e.name, e.tick, e.slot, e.req) for e in obs.trace.events()[:6]]
    assert head == GOLDEN_HEAD, head


# filled from the deterministic golden run; see test above
GOLDEN_HEAD = [
    ("admit", 1, 0, 0),
    ("prefill_chunk", 1, 0, 0),
    ("prefetch", -1, -1, -1),
    ("prefetch", -1, -1, -1),
    ("prefetch", -1, -1, -1),
    ("admit", 2, 1, 1),
]


# --------------------------------------------------------------------------- #
# cache-level and sharded-event emission                                      #
# --------------------------------------------------------------------------- #

def test_sharded_refresh_emits_gcd_exchange_events():
    from repro.serving.kv_cache_sharded import ShardedPagedKVCache

    cache = ShardedPagedKVCache(hbm_pages=16, page_size=4, n_shards=2,
                                mesh=None)
    obs = attach(cache, Observability())
    cache.register_request(0, list(range(20)))
    cache.refresh_tables()
    exch = [e for e in obs.trace.events() if e.kind == EV_GCD_EXCHANGE]
    assert len(exch) == cache.n_shards
    assert sorted(e.shard for e in exch) == list(range(cache.n_shards))
    assert sum(e.arg for e in exch) == sum(cache.last_scan.local_composites)


def test_attach_detach():
    m = SlotMachine(kv="vec", **CFG)
    obs = attach(m, Observability())
    assert m.obs is obs and m.pages.obs is obs
    attach(m, None)
    assert m.obs is None and m.pages.obs is None


# --------------------------------------------------------------------------- #
# export pipeline -> Chrome trace_event                                       #
# --------------------------------------------------------------------------- #

def test_export_roundtrip_through_trace_view(tmp_path):
    eng, obs = _traced(SlotMachine)
    profile.reset()
    with profile.profiling():
        with profile.kernel_scope("fake_kernel", items=7):
            pass
    path = tmp_path / "obs.json"
    obs.export_json(path)
    payload = json.loads(path.read_text())
    assert payload["schema"]["1"] == "admit"
    assert payload["trace"]["total"] == obs.trace.total
    assert payload["telemetry"]["ticks_seen"] == eng.ticks
    assert payload["kernel_launches"]["fake_kernel"]["items"] == 7

    chrome = convert(payload)
    evs = chrome["traceEvents"]
    assert chrome["displayTimeUnit"] == "ms"
    phases = {e["ph"] for e in evs}
    assert {"i", "C", "X", "M"} <= phases
    inst = [e for e in evs if e["ph"] == "i"]
    assert len(inst) == len(obs.trace.events())
    assert all("name" in e for e in evs)
    assert all("ts" in e for e in evs if e["ph"] != "M")
    # counter events carry their gauge value under args[name]
    ctr = next(e for e in evs if e["ph"] == "C")
    assert ctr["args"][ctr["name"]] is not None
    # kernel spans are laid end to end on pid 1
    spans = [e for e in evs if e["ph"] == "X"]
    assert spans and all(e["pid"] == 1 and e["dur"] >= 1 for e in spans)
    # the whole thing serializes (what chrome://tracing loads)
    json.dumps(chrome)


def test_trace_view_cli(tmp_path, capsys):
    from trace_view import main as tv_main

    _, obs = _traced(SlotOracle)
    src = tmp_path / "obs.json"
    dst = tmp_path / "chrome.json"
    obs.export_json(src)
    out = tv_main([str(src), str(dst)])
    assert dst.exists() and out["traceEvents"]
    assert "wrote" in capsys.readouterr().out
    assert json.loads(dst.read_text())["displayTimeUnit"] == "ms"
