"""Docs tree consistency: the documentation the code promises exists.

Wraps tools/check_doc_refs.py so the tier-1 suite enforces what CI
enforces: every ``DESIGN.md``/``README.md``/``docs/api.md`` reference in
a docstring or comment resolves to a real file, and every
``DESIGN.md §N`` citation resolves to a real section heading.
"""

import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "tools"))

import check_doc_refs  # noqa: E402


def test_doc_tree_exists():
    for f in ("README.md", "DESIGN.md", "docs/api.md"):
        assert (ROOT / f).exists(), f"missing documentation file {f}"


def test_all_doc_references_resolve():
    problems = check_doc_refs.check(ROOT)
    assert not problems, "\n".join(problems)


def test_api_md_dedup_examples_execute():
    """The docs/api.md COW-dedup section promises *executed* examples
    (ISSUE 9): every ```python block in it must run clean.  Blocks
    build on each other (the oracle from block 1 is re-used by the
    accounting block), so they share one namespace, in order."""
    import re
    text = (ROOT / "docs" / "api.md").read_text()
    start = text.index("## Cross-tenant COW shared-prefix dedup")
    end = text.index("## Large universes")
    blocks = re.findall(r"```python\n(.*?)```", text[start:end], re.S)
    assert blocks, "dedup section lost its examples"
    ns = {}
    for i, block in enumerate(blocks):
        exec(compile(block, f"<api.md dedup {i}>", "exec"), ns)


def test_api_md_large_universe_examples_execute():
    """The docs/api.md "Large universes" section promises *executed*
    examples (ISSUE 8): every ```python block in it must run clean."""
    import re
    text = (ROOT / "docs" / "api.md").read_text()
    start = text.index("## Large universes")
    end = text.index("## Results containers")
    blocks = re.findall(r"```python\n(.*?)```", text[start:end], re.S)
    assert blocks, "Large universes section lost its examples"
    for i, block in enumerate(blocks):
        exec(compile(block, f"<api.md large-universes {i}>", "exec"), {})


def test_api_md_observability_examples_execute():
    """The docs/api.md Observability section promises *executed*
    examples (ISSUE 10): every ```python block in it must run clean.
    Blocks build on each other (the driven machine/obs pair from the
    façade block feeds the trace-diff and telemetry blocks), so they
    share one namespace, in order."""
    import re
    text = (ROOT / "docs" / "api.md").read_text()
    start = text.index("## Observability")
    blocks = re.findall(r"```python\n(.*?)```", text[start:], re.S)
    assert blocks, "Observability section lost its examples"
    ns = {}
    for i, block in enumerate(blocks):
        exec(compile(block, f"<api.md observability {i}>", "exec"), ns)
