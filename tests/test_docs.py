"""Docs tree consistency: the documentation the code promises exists.

Wraps tools/check_doc_refs.py so the tier-1 suite enforces what CI
enforces: every ``DESIGN.md``/``README.md``/``docs/api.md`` reference in
a docstring or comment resolves to a real file, and every
``DESIGN.md §N`` citation resolves to a real section heading.
"""

import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "tools"))

import check_doc_refs  # noqa: E402


def test_doc_tree_exists():
    for f in ("README.md", "DESIGN.md", "docs/api.md"):
        assert (ROOT / f).exists(), f"missing documentation file {f}"


def test_all_doc_references_resolve():
    problems = check_doc_refs.check(ROOT)
    assert not problems, "\n".join(problems)
