"""Numerical equivalences that pin the optimized paths to naive math:
chunked attention == full, SSD chunked scan == recurrence, mLSTM
parallel == chunked == recurrent, MLA decode == MLA train."""

import numpy as np
import pytest

from hypothesis_compat import given, settings, st

import jax
import jax.numpy as jnp

from repro.models.attention import (attention_chunked, attention_full,
                                    decode_attention)
from repro.models.ssm import ssd_chunked, ssd_recurrent_step
from repro.models.xlstm import (mlstm_chunked, mlstm_parallel,
                                mlstm_recurrent_step)


def test_chunked_attention_matches_full():
    rng = np.random.default_rng(0)
    B, S, H, KV, D = 2, 128, 8, 2, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, D)), jnp.float32)
    full = attention_full(q, k, v, causal=True)
    for chunk in (16, 32, 64):
        ch = attention_chunked(q, k, v, chunk=chunk, causal=True)
        np.testing.assert_allclose(np.asarray(ch), np.asarray(full),
                                   rtol=1e-5, atol=1e-5)


def test_decode_attention_matches_full_last_row():
    rng = np.random.default_rng(1)
    B, S, H, KV, D = 2, 32, 4, 4, 8
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, D)), jnp.float32)
    full = attention_full(q, k, v, causal=True)
    dec = decode_attention(q[:, -1:], k, v,
                           jnp.full((B,), S, jnp.int32))
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, -1]), rtol=1e-5, atol=1e-5)


@given(st.integers(min_value=1, max_value=3),
       st.sampled_from([8, 16, 32]))
@settings(max_examples=10, deadline=None)
def test_ssd_chunked_equals_recurrence(seed, chunk):
    rng = np.random.default_rng(seed)
    B, S, H, P, G, N = 1, 64, 2, 4, 1, 8
    x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.05, 0.8, size=(B, S, H)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.3, 2.0, size=(H,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, G, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, S, G, N)), jnp.float32)
    y = ssd_chunked(x, dt, A, Bm, Cm, chunk)
    state = jnp.zeros((B, H, N, P))
    outs = []
    for t in range(S):
        o, state = ssd_recurrent_step(state, x[:, t], dt[:, t], A,
                                      Bm[:, t], Cm[:, t])
        outs.append(o)
    ref = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_mlstm_three_way_equivalence():
    rng = np.random.default_rng(4)
    B, S, H, D = 2, 48, 2, 8
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    ig = jnp.asarray(rng.normal(size=(B, S, H)), jnp.float32)
    fg = jnp.asarray(rng.normal(size=(B, S, H)) + 2.0, jnp.float32)
    par = mlstm_parallel(q, k, v, ig, fg)
    chk = mlstm_chunked(q, k, v, ig, fg, 16)
    state = {"C": jnp.zeros((B, H, D, D)), "n": jnp.zeros((B, H, D)),
             "m": jnp.full((B, H), -1e30)}
    outs = []
    for t in range(S):
        o, state = mlstm_recurrent_step(state, q[:, t], k[:, t], v[:, t],
                                        ig[:, t], fg[:, t])
        outs.append(o)
    rec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(chk), np.asarray(par),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(par), np.asarray(rec),
                               rtol=1e-3, atol=1e-3)


def test_gqa_grouping_matches_repeated_kv():
    """GQA einsum grouping == explicit KV repetition."""
    rng = np.random.default_rng(5)
    B, S, H, KV, D = 1, 16, 8, 2, 8
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, D)), jnp.float32)
    out = attention_full(q, k, v, causal=True)
    k_rep = jnp.repeat(k, H // KV, axis=2)
    v_rep = jnp.repeat(v, H // KV, axis=2)
    ref = attention_full(q, k_rep, v_rep, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
