"""Shared test-workload generators: hypothesis strategies + the
deterministic builders behind them.

Every randomized workload the suite drives caches with lives here, in
two layers:

  1. **Deterministic builders** — pure functions of a small spec
     (seed + sizes) that expand into concrete workloads:
     :func:`build_kv_ops` / :func:`apply_kv_ops` for paged-KV request
     streams, :func:`drive_kv` (the classic serving parity driver),
     :func:`build_expert_sets` / :func:`drive_expert` for router-driven
     MoE expert workloads, :func:`trace_zoo` / :func:`adversarial_trace`
     for simulator traces.  The ad-hoc randomized loops that used to
     live inline in ``tests/test_serving.py`` / ``tests/test_engine.py``
     now call these.
  2. **Hypothesis strategies** (via ``hypothesis_compat`` — clean SKIP
     when the package is absent) that sample the *specs*:
     :func:`kv_workload_specs` for serving-cache differential fuzzing
     (chain topologies with shared prefixes, 1-slot HBM, registry
     drops, eviction-adversarial sweeps),
     :func:`expert_workload_specs` for expert-cache fuzzing (skewed
     router popularity, repeated-group / disjoint-partition schedules,
     ``max_group`` overflow), :func:`trace_specs` for engine traces,
     :func:`adversarial_stream_specs` for recency-thrashing access
     streams.

Sampling specs rather than raw streams keeps shrinking effective (a
failing case minimizes to a tiny seed + sizes tuple) and lets the
differential tests replay the IDENTICAL abstract op sequence against
every cache implementation — selectors resolve against live state, so
two bit-equal caches see bit-equal concrete streams.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

__all__ = [
    "KVWorkloadSpec", "build_kv_ops", "apply_kv_ops", "drive_kv",
    "kv_workload_specs", "trace_zoo", "trace_specs", "make_trace",
    "adversarial_trace", "adversarial_stream_specs",
    "LimbUniverseSpec", "build_limb_universe", "limb_universe_specs",
    "ElasticEventSpec", "build_failure_schedule", "apply_elastic_event",
    "elastic_event_specs",
    "ExpertWorkloadSpec", "build_expert_sets", "drive_expert",
    "expert_workload_specs",
    "TenantMixSpec", "build_tenant_requests", "drive_tenants",
    "tenant_mix_specs", "dedup_mix_specs",
    "ArrivalSpec", "build_poisson_arrivals", "drive_slots",
    "arrival_specs",
    "HAVE_HYPOTHESIS", "given", "settings", "st",
]


# --------------------------------------------------------------------------- #
# paged-KV workloads (serving tier)                                           #
# --------------------------------------------------------------------------- #

@dataclass(frozen=True)
class KVWorkloadSpec:
    """Compact description of a serving workload; expanded by
    :func:`build_kv_ops` into an abstract op sequence."""

    seed: int = 0
    n_requests: int = 12
    n_touches: int = 160
    key_space: int = 400
    shared_pool: int = 32          # tokens available for shared prefixes
    max_tail: int = 28             # per-request tail length bound
    release: bool = True           # retire old requests mid-stream
    drop_primes: bool = False      # out-of-band Algorithm-1 prime drops
    sweeps: int = 0                # eviction-adversarial full-chain sweeps


def build_kv_ops(spec: KVWorkloadSpec) -> List[Tuple]:
    """Expand a spec into an abstract op list.

    Ops use *selectors* (resolved modulo live state at apply time), so
    the same list drives any cache implementation:

      ("register", rid, tokens)  — submit a request's prompt
      ("touch", a, b)            — touch live request a-th, page b-th
      ("sweep", a)               — touch every page of a live request in
                                   chain order (sequential re-read — the
                                   LRU-adversarial scan pattern)
      ("release", )              — retire the oldest live request
      ("drop", d)                — assigner.release a page's L2 prime
                                   (registry drop -> table rebuild path)
    """
    rng = np.random.default_rng(spec.seed)
    shared = list(rng.integers(0, spec.key_space, size=spec.shared_pool))
    ops: List[Tuple] = []
    per_req = max(1, spec.n_touches // max(1, spec.n_requests))
    for r in range(spec.n_requests):
        pfx = int(rng.integers(0, spec.shared_pool))
        tail = list(rng.integers(0, spec.key_space,
                                 size=int(rng.integers(4, spec.max_tail))))
        ops.append(("register", r, tuple(shared[:pfx] + tail)))
        if spec.drop_primes and rng.integers(4) == 0:
            ops.append(("drop", int(rng.integers(1 << 30))))
        for _ in range(per_req):
            ops.append(("touch", int(rng.integers(1 << 30)),
                        int(rng.integers(1 << 30))))
        if spec.sweeps and rng.integers(max(1, spec.n_requests
                                            // spec.sweeps)) == 0:
            ops.append(("sweep", int(rng.integers(1 << 30))))
        if spec.release and r > 6 and rng.integers(3) == 0:
            ops.append(("release",))
    return ops


def apply_kv_ops(kv, ops: Sequence[Tuple], schedule=None,
                 on_event=None) -> List[str]:
    """Replay an abstract op list against one cache; returns the tier
    string of every touch (the differential-comparison payload).

    ``schedule`` (a :func:`build_failure_schedule` dict: op index ->
    event list) injects chaos events BEFORE the op at that index.  Each
    event goes through ``on_event(kv, event)`` when given, else
    :func:`apply_elastic_event` — which no-ops kill/resize on caches
    without elastic hooks, so the SAME schedule replays against the
    scalar oracle and the elastic cache (the parity contract's whole
    point: elastic events must be invisible to placement).
    """
    from repro.core.primes import CacheLevel

    tiers: List[str] = []
    live: List[int] = []
    fire = on_event if on_event is not None else apply_elastic_event
    for i, op in enumerate(ops):
        if schedule:
            for ev in schedule.get(i, ()):
                fire(kv, ev)
        kind = op[0]
        if kind == "register":
            _, rid, tokens = op
            kv.register_request(rid, list(tokens))
            live.append(rid)
        elif kind == "touch":
            _, a, b = op
            if not live:
                continue
            rid = live[a % len(live)]
            chain = kv.chains.get(rid) or ()
            if chain:
                tiers.append(kv.touch(rid, b % len(chain)))
        elif kind == "sweep":
            (_, a) = op
            if not live:
                continue
            rid = live[a % len(live)]
            chain = kv.chains.get(rid) or ()
            if chain:
                tiers.extend(kv.touch_batch([(rid, j)
                                             for j in range(len(chain))]))
        elif kind == "release":
            if live:
                kv.release_request(live.pop(0))
        elif kind == "drop":
            (_, d) = op
            if kv._next_page:
                kv.assigner.release(d % kv._next_page, CacheLevel.L2)
        else:                       # pragma: no cover - builder invariant
            raise ValueError(f"unknown op {kind!r}")
    return tiers


def drive_kv(kv, seed: int, n_requests: int = 16,
             n_touches: int = 400) -> List[str]:
    """The classic serving parity driver (shared-prefix request mix,
    interleaved registration and touches, releases) — byte-identical to
    the loop that used to live in ``tests/test_serving.py``."""
    rng = np.random.default_rng(seed)
    shared = list(rng.integers(0, 400, size=32))
    tiers: List[str] = []
    live: List[int] = []
    for r in range(n_requests):
        pfx = int(rng.integers(0, 32))
        tail = list(rng.integers(0, 400, size=int(rng.integers(4, 28))))
        kv.register_request(r, shared[:pfx] + tail)
        live.append(r)
        for _ in range(n_touches // n_requests):
            q = live[int(rng.integers(len(live)))]
            if kv.chains[q]:
                tiers.append(kv.touch(q, int(rng.integers(
                    len(kv.chains[q])))))
        if len(live) > 6 and rng.integers(3) == 0:
            kv.release_request(live.pop(0))
    return tiers


def kv_workload_specs():
    """Strategy over serving workload specs, biased toward the edges the
    parity suite cares about: degenerate 1-slot HBM interleavings come
    from the caller's cache config; this spec covers chain topology
    (shared-prefix depth), registry drops, and adversarial sweeps."""
    return st.builds(
        KVWorkloadSpec,
        seed=st.integers(min_value=0, max_value=2**16),
        n_requests=st.integers(min_value=3, max_value=18),
        n_touches=st.integers(min_value=10, max_value=240),
        key_space=st.sampled_from([60, 400]),
        shared_pool=st.sampled_from([8, 32]),
        max_tail=st.sampled_from([6, 28]),
        release=st.booleans(),
        drop_primes=st.booleans(),
        sweeps=st.sampled_from([0, 2]),
    )


# --------------------------------------------------------------------------- #
# chaos fault-injection schedules (elastic tier)                              #
# --------------------------------------------------------------------------- #

@dataclass(frozen=True)
class ElasticEventSpec:
    """Compact description of a chaos fault-injection schedule; expanded
    by :func:`build_failure_schedule` into op-indexed events for
    :func:`apply_kv_ops` / :func:`drive_tenants` (the elastic chaos
    fuzz's input — tests/test_elastic.py)."""

    seed: int = 0
    n_events: int = 4
    kill: bool = True              # shard loss (fail_shard)
    defer: bool = True             # some kills recover lazily (next touch)
    resize: bool = True            # live shard-count changes
    straggle: bool = False         # slow-node reports (controller-driven)
    drop: bool = False             # out-of-band Algorithm-1 prime drops
    shard_choices: Tuple[int, ...] = (2, 4)


def build_failure_schedule(spec: ElasticEventSpec, n_ops: int):
    """Expand a spec into ``{op_index: [event, ...]}`` (events fire
    before the op at that index).  Event tuples:

      ("kill", sel, deferred)  — fail shard sel % n_shards; recover
                                 immediately unless ``deferred`` (then
                                 failover-on-demand recovers it at the
                                 next touch)
      ("resize", n)            — live re-stripe to n shards
      ("straggle", sel, slow)  — node sel reports ``slow``x step times
                                 (meaningful only via a controller's
                                 StragglerMonitor; placement no-op)
      ("drop", sel)            — assigner.release a page's prime — a
                                 WORKLOAD mutation, applied identically
                                 to every cache incl. the oracle
    """
    rng = np.random.default_rng(spec.seed)
    kinds = ([("kill",)] if spec.kill else []) \
        + ([("resize",)] if spec.resize else []) \
        + ([("straggle",)] if spec.straggle else []) \
        + ([("drop",)] if spec.drop else [])
    schedule: dict = {}
    if not kinds or n_ops < 2:
        return schedule
    for _ in range(spec.n_events):
        idx = int(rng.integers(1, n_ops))
        (kind,) = kinds[int(rng.integers(len(kinds)))]
        if kind == "kill":
            ev = ("kill", int(rng.integers(1 << 30)),
                  bool(spec.defer and rng.integers(2)))
        elif kind == "resize":
            ev = ("resize", int(spec.shard_choices[
                int(rng.integers(len(spec.shard_choices)))]))
        elif kind == "straggle":
            ev = ("straggle", int(rng.integers(1 << 30)),
                  float(2.0 + rng.integers(4)))
        else:
            ev = ("drop", int(rng.integers(1 << 30)))
        schedule.setdefault(idx, []).append(ev)
    return schedule


def apply_elastic_event(kv, ev: Tuple) -> None:
    """Default chaos-event dispatcher.  Elastic-only events (kill,
    resize) no-op on caches without the hooks — the oracle replays the
    same schedule and must end bit-identical; ``drop`` mutates the
    workload itself, so it applies to EVERY cache."""
    from repro.core.primes import CacheLevel

    kind = ev[0]
    if kind == "kill":
        if hasattr(kv, "fail_shard"):
            s = ev[1] % kv.n_shards
            kv.fail_shard(s)
            if not ev[2]:
                kv.recover_shard(s)
    elif kind == "resize":
        if hasattr(kv, "resize") and ev[1] != getattr(kv, "n_shards", None):
            kv.resize(ev[1])
    elif kind == "straggle":
        pass                        # needs a controller; placement no-op
    elif kind == "drop":
        if kv._next_page:
            kv.assigner.release(ev[1] % kv._next_page, CacheLevel.L2)
    else:                           # pragma: no cover - builder invariant
        raise ValueError(f"unknown event {kind!r}")


def elastic_event_specs():
    """Strategy over chaos schedules: kill/resize mixes with deferred
    recoveries, optional straggler reports and prime drops."""
    return st.builds(
        ElasticEventSpec,
        seed=st.integers(min_value=0, max_value=2**16),
        n_events=st.integers(min_value=1, max_value=6),
        kill=st.booleans(),
        defer=st.booleans(),
        resize=st.booleans(),
        straggle=st.just(False),
        drop=st.booleans(),
        shard_choices=st.just((2, 4)),
    )


# --------------------------------------------------------------------------- #
# multi-tenant workloads (tenancy tier)                                       #
# --------------------------------------------------------------------------- #

@dataclass(frozen=True)
class TenantMixSpec:
    """Compact description of a mixed-tenant serving workload; expanded
    by :func:`build_tenant_requests` into a tenant-tagged abstract op
    sequence (the tenancy differential fuzz's input —
    tests/test_tenancy.py)."""

    seed: int = 0
    n_tenants: int = 2
    n_requests: int = 10
    n_touches: int = 120
    key_space: int = 300
    shared_pool: int = 24          # per-tenant shared-prefix token pool
    max_tail: int = 20             # per-request tail length bound
    hot_tenant: bool = False       # tenant 0 draws extra zipf-hot touches
    scanner_tenant: bool = False   # last tenant sweeps whole chains
    cross_prefix: bool = False     # tenants submit IDENTICAL token
    #                                prefixes (isolation must still keep
    #                                their pages distinct)
    release: bool = True           # retire old requests mid-stream
    drop_primes: bool = False      # out-of-band Algorithm-1 prime drops


def build_tenant_requests(spec: TenantMixSpec) -> List[Tuple]:
    """Expand a spec into a tenant-tagged abstract op list.

    Ops mirror :func:`build_kv_ops` (selectors resolved modulo live
    state at apply time) with tenant-aware registration:

      ("register", rid, tenant, tokens) — submit a request for a tenant
      ("touch", a, b)                   — touch live request a-th, page b-th
      ("sweep", a)                      — full-chain sequential re-read
                                          (the scanner/adversarial pattern)
      ("release", )                     — retire the oldest live request
      ("drop", d)                       — assigner.release a page's prime
    """
    rng = np.random.default_rng(spec.seed)
    T = max(1, spec.n_tenants)
    pools = [list(rng.integers(0, spec.key_space, size=spec.shared_pool))
             for _ in range(T)]
    if spec.cross_prefix:
        pools = [list(pools[0]) for _ in range(T)]   # identical tokens
    ops: List[Tuple] = []
    per_req = max(1, spec.n_touches // max(1, spec.n_requests))
    scanner = T - 1
    for r in range(spec.n_requests):
        t = int(rng.integers(T))
        pfx = int(rng.integers(0, spec.shared_pool))
        tail_n = int(rng.integers(4, spec.max_tail))
        if spec.scanner_tenant and t == scanner:
            tail_n = spec.max_tail + 8               # long chains to sweep
        tail = list(rng.integers(0, spec.key_space, size=tail_n))
        ops.append(("register", r, t, tuple(pools[t][:pfx] + tail)))
        if spec.drop_primes and rng.integers(4) == 0:
            ops.append(("drop", int(rng.integers(1 << 30))))
        n_t = per_req * (3 if spec.hot_tenant and t == 0 else 1)
        for _ in range(n_t):
            ops.append(("touch", int(rng.integers(1 << 30)),
                        int(rng.integers(1 << 30))))
        if spec.scanner_tenant and t == scanner:
            ops.append(("sweep", r))
        if spec.release and r > 4 and rng.integers(3) == 0:
            ops.append(("release",))
    return ops


def drive_tenants(kv, ops: Sequence[Tuple], step_hook=None,
                  schedule=None, on_event=None) -> List[str]:
    """Replay a tenant-tagged op list against one tenanted cache;
    returns every touch's tier string (the differential-comparison
    payload).  ``step_hook(kv)``, when given, runs after EVERY op — the
    tenancy fuzz passes the namespace isolation checker here so the
    invariant is proven at every intermediate state, not just at the
    end.  ``schedule``/``on_event`` inject chaos events exactly as in
    :func:`apply_kv_ops` (the elastic x tenancy composition fuzz)."""
    from repro.core.primes import CacheLevel

    tiers: List[str] = []
    live: List[int] = []
    fire = on_event if on_event is not None else apply_elastic_event
    for i, op in enumerate(ops):
        if schedule:
            for ev in schedule.get(i, ()):
                fire(kv, ev)
        kind = op[0]
        if kind == "register":
            _, rid, tenant, tokens = op
            kv.register_request(rid, list(tokens), tenant=tenant)
            live.append(rid)
        elif kind == "touch":
            _, a, b = op
            if live:
                rid = live[a % len(live)]
                chain = kv.chains.get(rid) or ()
                if chain:
                    tiers.append(kv.touch(rid, b % len(chain)))
        elif kind == "sweep":
            (_, a) = op
            if live:
                rid = live[a % len(live)]
                chain = kv.chains.get(rid) or ()
                if chain:
                    tiers.extend(kv.touch_batch(
                        [(rid, j) for j in range(len(chain))]))
        elif kind == "release":
            if live:
                kv.release_request(live.pop(0))
        elif kind == "drop":
            (_, d) = op
            if kv._next_page:
                kv.assigner.release(d % kv._next_page, CacheLevel.L2)
        else:                       # pragma: no cover - builder invariant
            raise ValueError(f"unknown op {kind!r}")
        if step_hook is not None:
            step_hook(kv)
    return tiers


def tenant_mix_specs():
    """Strategy over mixed-tenant workload specs, biased toward the
    edges the tenancy parity suite cares about: hot/scanner tenant
    mixes, identical cross-tenant prefixes (content-isolation path),
    releases, and out-of-band prime drops (degenerate quotas come from
    the caller's cache config)."""
    return st.builds(
        TenantMixSpec,
        seed=st.integers(min_value=0, max_value=2**16),
        n_tenants=st.sampled_from([1, 2, 4]),
        n_requests=st.integers(min_value=3, max_value=12),
        n_touches=st.integers(min_value=10, max_value=140),
        key_space=st.sampled_from([60, 300]),
        shared_pool=st.sampled_from([8, 24]),
        max_tail=st.sampled_from([6, 20]),
        hot_tenant=st.booleans(),
        scanner_tenant=st.booleans(),
        cross_prefix=st.booleans(),
        release=st.booleans(),
        drop_primes=st.booleans(),
    )


def dedup_mix_specs():
    """Tenant mixes biased to the dedup paths (tests/test_dedup.py):
    identical cross-tenant token pools are ALWAYS on — the shared-
    system-prompt workload where admissions hit, promote, and COW off
    shared pages — with >= 2 tenants so promotion is reachable.  Prime
    drops stay off (a dropped prime under a refcounted shared page is
    the recycling fuzz's job, not the lifecycle fuzz's)."""
    return st.builds(
        TenantMixSpec,
        seed=st.integers(min_value=0, max_value=2**16),
        n_tenants=st.sampled_from([2, 4]),
        n_requests=st.integers(min_value=4, max_value=12),
        n_touches=st.integers(min_value=10, max_value=140),
        key_space=st.sampled_from([60, 300]),
        shared_pool=st.sampled_from([8, 24]),
        max_tail=st.sampled_from([6, 20]),
        hot_tenant=st.booleans(),
        scanner_tenant=st.booleans(),
        cross_prefix=st.just(True),
        release=st.booleans(),
        drop_primes=st.just(False),
    )


# --------------------------------------------------------------------------- #
# open-loop arrival traces (continuous-batching tier)                         #
# --------------------------------------------------------------------------- #

@dataclass(frozen=True)
class ArrivalSpec:
    """Compact description of an open-loop Poisson arrival trace;
    expanded by :func:`build_poisson_arrivals` into concrete
    (arrival-tick, prompt, max_new, tenant) tuples — the
    continuous-batching differential fuzz's input
    (tests/test_serving_batching.py)."""

    seed: int = 0
    n_requests: int = 24
    rate: float = 1.5              # mean requests per engine tick
    burst_frac: float = 0.0        # fraction front-loaded at tick 0 ...
    silence_ticks: int = 0         # ... followed by this much dead air
    min_prompt: int = 1            # prompt-length bounds: (1, 6) is the
    max_prompt: int = 24           # all-short mix, (40, 90) all-long
    max_new: int = 10              # decode-demand upper bound (ragged)
    shared_pool: int = 16          # tokens available for shared prefixes
    key_space: int = 200
    n_tenants: int = 1


def build_poisson_arrivals(spec: ArrivalSpec) -> List[Tuple]:
    """Expand a spec into ``(arrival, prompt, max_new, tenant)`` tuples
    in submission order.  Inter-arrival gaps are exponential at
    ``spec.rate`` (open-loop: the trace does not react to the engine);
    ``burst_frac``/``silence_ticks`` shape the burst-then-silence
    adversarial mix.  Prompts draw a shared prefix + random tail like
    :func:`build_kv_ops`, so chain discovery and gcd sharing stay
    exercised under load.  All values are absolute — the same list
    replays bit-identically into any slot engine."""
    from repro.serving.slots import poisson_arrival_ticks

    rng = np.random.default_rng(spec.seed)
    ticks = poisson_arrival_ticks(
        spec.n_requests, rate=spec.rate, seed=spec.seed,
        burst_frac=spec.burst_frac, silence_ticks=spec.silence_ticks)
    shared = list(rng.integers(0, spec.key_space, size=spec.shared_pool))
    out: List[Tuple] = []
    lo = max(1, spec.min_prompt)
    hi = max(lo + 1, spec.max_prompt)
    for i, t in enumerate(ticks):
        n = int(rng.integers(lo, hi))
        pfx = int(rng.integers(0, min(spec.shared_pool, n) + 1))
        tail = [int(x) for x in rng.integers(0, spec.key_space,
                                             size=n - pfx)]
        out.append((int(t), tuple(shared[:pfx] + tail),
                    int(rng.integers(1, max(2, spec.max_new))),
                    int(rng.integers(spec.n_tenants))
                    if spec.n_tenants > 1 else 0))
    return out


def drive_slots(engine, arrivals: Sequence[Tuple], schedule=None,
                on_event=None, step_hook=None,
                max_ticks: int = 100_000) -> List[str]:
    """Submit an arrival trace into a slot engine and tick it to idle;
    returns the engine's full tier log (the differential-comparison
    payload).  ``schedule`` (a :func:`build_failure_schedule` dict:
    tick index -> event list) injects chaos events against the
    engine's page cache BEFORE the step at that tick, exactly as
    :func:`apply_kv_ops` does per op — the elastic x batching
    composition fuzz.  ``step_hook(engine)``, when given, runs after
    every tick (the tenancy fuzz proves isolation at each one)."""
    for arrival, prompt, max_new, tenant in arrivals:
        engine.submit(list(prompt), max_new_tokens=max_new,
                      tenant=tenant, arrival=arrival)
    fire = on_event if on_event is not None else apply_elastic_event
    for _ in range(max_ticks):
        if engine.idle():
            return engine.tier_log
        if schedule:
            for ev in schedule.get(engine.now, ()):
                fire(engine.pages, ev)
        engine.step()
        if step_hook is not None:
            step_hook(engine)
    raise RuntimeError(f"slot engine failed to drain within "
                       f"{max_ticks} ticks")


def arrival_specs():
    """Strategy over open-loop arrival specs, biased toward the edges
    the batching parity suite cares about: all-short vs all-long prompt
    mixes, burst-then-silence traffic, ragged decode demands, multi-
    tenant tags (degenerate 1-slot engines and preemption pressure come
    from the caller's engine config)."""
    return st.builds(
        ArrivalSpec,
        seed=st.integers(min_value=0, max_value=2**16),
        n_requests=st.integers(min_value=2, max_value=28),
        rate=st.sampled_from([0.3, 1.5, 6.0]),
        burst_frac=st.sampled_from([0.0, 0.5, 1.0]),
        silence_ticks=st.sampled_from([0, 12]),
        min_prompt=st.sampled_from([1, 6, 40]),
        max_prompt=st.sampled_from([6, 24, 90]),
        max_new=st.sampled_from([2, 10, 24]),
        shared_pool=st.sampled_from([4, 16]),
        key_space=st.sampled_from([60, 200]),
        n_tenants=st.sampled_from([1, 2]),
    )


# --------------------------------------------------------------------------- #
# MoE expert workloads (serving tier)                                         #
# --------------------------------------------------------------------------- #

@dataclass(frozen=True)
class ExpertWorkloadSpec:
    """Compact description of a router-driven expert workload; expanded
    by :func:`build_expert_sets` into per-step batches of top-k sets."""

    seed: int = 0
    n_experts: int = 32
    n_steps: int = 60
    batch: int = 4                 # router sets per decode step
    group_size: int = 4            # top-k draw size (> max_group hits the cap)
    n_groups: int = 12             # co-activation pool size
    zipf_a: float = 1.0            # expert-popularity skew of group draws
    disjoint: bool = False         # adversarial: groups partition the experts
    repeat_hot: bool = False       # adversarial: one group dominates
    oversize_every: int = 0        # every k-th step adds a fresh oversized
    #                                draw (cap-collision / dedup edges)


def build_expert_sets(spec: ExpertWorkloadSpec) -> List[List[Tuple[int, ...]]]:
    """Expand a spec into per-decode-step batches of router top-k sets.

    The same concrete sets drive every cache implementation (expert ids
    are absolute, not selectors: the expert universe is fixed at
    construction), so two bit-equal caches see bit-equal streams.
    """
    rng = np.random.default_rng(spec.seed)
    k = max(1, min(spec.group_size, spec.n_experts))
    if spec.disjoint:
        perm = rng.permutation(spec.n_experts)
        pool = [tuple(int(e) for e in perm[i:i + k])
                for i in range(0, spec.n_experts - k + 1, k)]
        pool = pool[:max(1, spec.n_groups)] or [tuple(range(k))]
    else:
        pop = 1.0 / np.arange(1, spec.n_experts + 1) ** spec.zipf_a
        pop /= pop.sum()
        pool = [tuple(int(e) for e in rng.choice(
            spec.n_experts, size=k, replace=False, p=pop))
            for _ in range(max(1, spec.n_groups))]
    steps: List[List[Tuple[int, ...]]] = []
    for t in range(spec.n_steps):
        sets = []
        for _ in range(spec.batch):
            if spec.repeat_hot and rng.integers(2) == 0:
                sets.append(pool[0])
            else:
                sets.append(pool[int(rng.integers(len(pool)))])
        if spec.oversize_every and t % spec.oversize_every == 0:
            big = min(spec.n_experts, 2 * k + 1)
            sets.append(tuple(int(e) for e in rng.choice(
                spec.n_experts, size=big, replace=False)))
        steps.append(sets)
    return steps


def drive_expert(ec, step_batches: Sequence[Sequence[Tuple[int, ...]]]
                 ) -> List[Tuple]:
    """Replay per-step router batches against one expert cache — each
    step is ONE ``observe_routing`` + ONE ``activate_batch`` call, the
    serving engine's exact calling convention; returns every per-set
    tier decision (the differential-comparison payload)."""
    tiers: List[Tuple] = []
    for batch in step_batches:
        ec.observe_routing(batch)
        for t in ec.activate_batch(batch):
            tiers.append(tuple(sorted(t.items())))
    return tiers


def expert_workload_specs():
    """Strategy over expert workload specs, biased toward the parity
    edges: skewed popularity, adversarial repeated-group and
    disjoint-partition schedules, oversized draws that overflow
    ``max_group`` (degenerate 1-slot HBM comes from the caller's cache
    config)."""
    return st.builds(
        ExpertWorkloadSpec,
        seed=st.integers(min_value=0, max_value=2**16),
        n_experts=st.sampled_from([4, 16, 48]),
        n_steps=st.integers(min_value=5, max_value=60),
        batch=st.integers(min_value=1, max_value=6),
        group_size=st.integers(min_value=2, max_value=12),
        n_groups=st.sampled_from([2, 8, 24]),
        zipf_a=st.sampled_from([0.0, 1.0, 1.6]),
        disjoint=st.booleans(),
        repeat_hot=st.booleans(),
        oversize_every=st.sampled_from([0, 3]),
    )


# --------------------------------------------------------------------------- #
# multi-limb composite universes (wide-registry tier, DESIGN.md §11)          #
# --------------------------------------------------------------------------- #

@dataclass(frozen=True)
class LimbUniverseSpec:
    """Compact description of a squarefree-composite universe over a
    prime pool; expanded by :func:`build_limb_universe` into concrete
    exact Python ints — the limb-kernel differential fuzz's input
    (tests/test_limbs.py)."""

    seed: int = 0
    n_pool: int = 64               # prime pool size
    n_composites: int = 24
    max_factors: int = 20          # factors per composite (chain depth)
    max_bits: int = 256            # registry chunk width under test
    big_primes: bool = True        # draw near the 31-bit kernel limb cap


def build_limb_universe(spec: LimbUniverseSpec):
    """Expand a spec into ``(pool, composites)``: a sorted prime pool
    and squarefree products of pool subsets, every product strictly
    under ``2**spec.max_bits``.  All values are exact Python ints — the
    oracle side of the differential fuzz; the kernel side packs them
    with :func:`repro.core.composite.pack_limbs`."""
    from repro.core.primes import segmented_sieve, sieve_primes

    rng = np.random.default_rng(spec.seed)
    small = [int(p) for p in sieve_primes(10_000)[5:]]
    pool = set(small[: spec.n_pool])
    if spec.big_primes:
        # the top of the kernel limb word (primes < 2**31, DESIGN.md §11)
        lo = (1 << 31) - 20_000
        pool |= {int(p) for p in segmented_sieve(lo, 1 << 31)}
        pool |= {1_000_003, 999_983, 104_729, 15_485_863}
    pool = sorted(pool)
    comps = []
    for _ in range(spec.n_composites):
        k = int(rng.integers(1, spec.max_factors + 1))
        ps = rng.choice(len(pool), size=min(k, len(pool)), replace=False)
        c = 1
        for i in ps:
            nxt = c * pool[int(i)]
            if nxt.bit_length() >= spec.max_bits:
                break
            c = nxt
        if c > 1:
            comps.append(c)
    return pool, (comps or [pool[0] * pool[1]])


def limb_universe_specs():
    """Strategy over limb-universe specs, biased toward the edges the
    limb kernels care about: single-limb vs many-limb widths, chains
    deep enough to span limbs, primes adjacent to the 31-bit cap."""
    return st.builds(
        LimbUniverseSpec,
        seed=st.integers(min_value=0, max_value=2**16),
        n_pool=st.sampled_from([8, 64, 200]),
        n_composites=st.integers(min_value=1, max_value=32),
        max_factors=st.sampled_from([2, 8, 40]),
        max_bits=st.sampled_from([64, 96, 256, 1024]),
        big_primes=st.booleans(),
    )


# --------------------------------------------------------------------------- #
# simulator traces (engine tier)                                              #
# --------------------------------------------------------------------------- #

def trace_zoo(length: int, seeds: Sequence[int] = (1, 2)) -> list:
    """The engine suite's standard covering set: skewed zipf traffic,
    relationship-rich db joins, and the LRU-adversarial sequential
    scan."""
    from repro.core import db_join_trace, scan_trace, zipf_trace

    return [
        zipf_trace(n_keys=400, n_accesses=length, seed=seeds[0]),
        db_join_trace(n_orders=150, n_customers=40, n_items=80,
                      n_queries=length, seed=seeds[1]),
        scan_trace(n_keys=length // 3, n_passes=3),
    ]


def make_trace(kind: str, length: int, seed: int):
    """One trace by kind — the expansion target of :func:`trace_specs`."""
    from repro.core import (db_join_trace, graph_walk_trace, scan_trace,
                            zipf_trace)

    if kind == "zipf":
        return zipf_trace(n_keys=300, n_accesses=length, seed=seed)
    if kind == "db":
        return db_join_trace(n_orders=120, n_customers=30, n_items=60,
                             n_queries=length, seed=seed)
    if kind == "graph":
        return graph_walk_trace(n_keys=250, relationship_density=0.6,
                                n_accesses=length, seed=seed)
    if kind == "scan":
        return scan_trace(n_keys=max(4, length // 3), n_passes=3)
    if kind == "adversarial":
        return adversarial_trace(length=length, seed=seed)
    raise ValueError(f"unknown trace kind {kind!r}")


def adversarial_trace(length: int = 1200, capacity: int = 96,
                      seed: int = 0, hot_keys: int = 8):
    """Eviction-adversarial access stream: cyclic sweeps over a working
    set one larger than the given capacity (every access misses under
    plain LRU of that size) interleaved with a small reused hot set —
    the recency-thrash pattern scan-resistant policies (2Q/ARC/LIRS)
    exist to survive."""
    from repro.core.traces import Trace

    rng = np.random.default_rng(seed)
    sweep_keys = capacity + 1
    acc = []
    pos = 0
    for _ in range(length):
        if rng.integers(4) == 0:
            acc.append(sweep_keys + int(rng.integers(hot_keys)))
        else:
            acc.append(pos % sweep_keys)
            pos += 1
    return Trace(name=f"adversarial[{capacity}]",
                 accesses=np.asarray(acc, dtype=np.int64),
                 relationships=[], n_keys=sweep_keys + hot_keys,
                 meta={"kind": "adversarial"})


def trace_specs():
    """Strategy over (kind, length, seed) simulator-trace specs."""
    return st.tuples(
        st.sampled_from(["zipf", "db", "graph", "scan", "adversarial"]),
        st.integers(min_value=64, max_value=900),
        st.integers(min_value=0, max_value=2**16),
    )


def adversarial_stream_specs():
    """Strategy over eviction-adversarial stream parameters."""
    return st.tuples(
        st.integers(min_value=64, max_value=600),    # length
        st.sampled_from([4, 16, 96]),                # thrashed capacity
        st.integers(min_value=0, max_value=2**16),   # seed
    )
