"""Sharding rules: every leaf gets a valid spec on the production mesh
shapes (divisibility fallback never produces an invalid partition)."""

import numpy as np
import pytest

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.models import build_model
from repro.sharding import partition as pt
from repro.training.train_loop import abstract_train_state


def _fake_mesh(shape, axes):
    """AbstractMesh carries axis sizes without needing real devices.

    jax 0.4.x takes one ``((name, size), ...)`` tuple; newer jax takes
    ``(shape, axis_names)`` — support both.
    """
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(zip(axes, shape)))
    except TypeError:
        return AbstractMesh(shape, axes)


MESH = _fake_mesh((16, 16), ("data", "model"))
MESH3 = _fake_mesh((2, 16, 16), ("pod", "data", "model"))


def _check_specs(abstract_tree, shardings, mesh):
    leaves_a = jax.tree.leaves(abstract_tree)
    leaves_s = jax.tree.leaves(
        shardings, is_leaf=lambda x: hasattr(x, "spec"))
    assert len(leaves_a) == len(leaves_s)
    for arr, sh in zip(leaves_a, leaves_s):
        spec = sh.spec
        assert len(spec) <= arr.ndim, (arr.shape, spec)
        for dim, entry in zip(arr.shape, spec):
            if entry is None:
                continue
            axes = (entry,) if isinstance(entry, str) else entry
            total = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % total == 0, (arr.shape, spec)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
@pytest.mark.parametrize("mesh", [MESH, MESH3], ids=["pod", "multipod"])
def test_param_shardings_valid_full_config(arch_id, mesh):
    cfg = get_config(arch_id)
    model = build_model(cfg)
    params = model.param_specs()
    sh = pt.params_shardings(params, mesh, cfg)
    _check_specs(params, sh, mesh)


@pytest.mark.parametrize("arch_id", ["qwen3-32b", "kimi-k2-1t-a32b",
                                     "zamba2-7b", "xlstm-1.3b"])
def test_opt_state_shardings_valid(arch_id):
    cfg = get_config(arch_id)
    model = build_model(cfg)
    state = abstract_train_state(model)
    sh = pt.opt_state_shardings(state.opt_state, state.params, MESH, cfg)
    _check_specs(state.opt_state, sh, MESH)


@pytest.mark.parametrize("arch_id", ["gemma-2b", "deepseek-v2-236b",
                                     "zamba2-7b"])
def test_cache_shardings_valid(arch_id):
    from repro.configs import SHAPES
    cfg = get_config(arch_id)
    model = build_model(cfg)
    shape = SHAPES[2]  # decode_32k
    cache = model.cache_specs(shape)
    sh = pt.cache_shardings(cache, MESH, cfg)
    _check_specs(cache, sh, MESH)


def test_seq_shard_long_context():
    from repro.configs import SHAPES
    cfg = get_config("zamba2-7b")
    model = build_model(cfg)
    shape = SHAPES[3]  # long_500k, batch=1
    cache = model.cache_specs(shape)
    sh = pt.cache_shardings(cache, MESH, cfg, seq_shard=True)
    _check_specs(cache, sh, MESH)
    # the KV caches must actually be sequence-sharded
    k_sh = sh["k"]
    assert k_sh.spec[2] is not None


def test_tp_weights_are_sharded_over_model():
    cfg = get_config("qwen3-32b")
    model = build_model(cfg)
    params = model.param_specs()
    sh = pt.params_shardings(params, MESH, cfg)
    wq = sh["dense_layers"]["attn"]["wq"].spec
    assert "model" in jax.tree.leaves(tuple(wq))
    emb = sh["embed"]["table"].spec
    assert emb[0] == "model"                 # vocab sharded


def test_gemma_mqa_kv_fallback():
    """gemma kv=1 cannot shard heads over model=16 -> falls back without
    producing an invalid spec (head_dim 256 divides instead)."""
    cfg = get_config("gemma-2b")
    model = build_model(cfg)
    params = model.param_specs()
    sh = pt.params_shardings(params, MESH, cfg)
    wk = sh["dense_layers"]["attn"]["wk"].spec
    # (L, d, kv=1, hd=256): kv dim must NOT be sharded
    assert wk[2] is None


def test_divisibility_fallback_warns_exactly_once(caplog):
    """The replicate fallback logs ONE warning per distinct
    (dim, axes, size) — not one per layer, not zero: gemma's single KV
    head (1 vs model=16) appears in every attention block but must
    surface exactly once, and a repeat run adds nothing."""
    import logging

    cfg = get_config("gemma-2b")
    model = build_model(cfg)
    params = model.param_specs()
    pt.reset_fallback_warnings()
    with caplog.at_level(logging.WARNING, logger=pt.log.name):
        pt.params_shardings(params, MESH, cfg)
    kv_head = [r for r in caplog.records
               if "dim 1 does not divide" in r.getMessage()
               and "'model'" in r.getMessage()]
    assert len(kv_head) == 1, [r.getMessage() for r in caplog.records]
    n_first = len(caplog.records)
    assert n_first >= 1
    with caplog.at_level(logging.WARNING, logger=pt.log.name):
        pt.params_shardings(params, MESH, cfg)   # dedup across calls
    assert len(caplog.records) == n_first
    pt.reset_fallback_warnings()
