"""Cross-tenant COW shared-prefix dedup (DESIGN.md §12) — differential
and property fuzz.

The dedup layer is pinned by the same discipline as every other tier:

  * the scalar :class:`DedupOracle` is the bit-exact reference; the
    vectorized / sharded / elastic dedup caches must reproduce every
    ``DEDUP_COUNTERS`` entry, tier string, HBM LRU order, prefetch log,
    per-tenant stat, refcount map, and charged-share vector under any
    drawn interleaving, with the namespace isolation theorem proven at
    every step;
  * a refcount lifecycle fuzz drives admit / share / diverge /
    complete / evict interleavings and asserts at every op boundary:
    refcounts never go negative, a referenced HBM-resident shared page
    is never evicted, COW allocates a fresh prime while pre-existing
    composites stay untouched, and ``check_isolation`` stays green;
  * the content-addressing collision regression (``hash(-1) ==
    hash(-2)`` in CPython) pins the page-addressing bugfix: two
    distinct token prefixes whose content keys collide under ``hash``
    must land on distinct pages in every cache flavor;
  * composition: dedup x ``SlotMachine`` continuous batching (admission
    prefill skip included) and dedup x wide (``max_bits > 63``)
    registries stay bit-exact.
"""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st
from strategies import (ArrivalSpec, TenantMixSpec, build_poisson_arrivals,
                        build_tenant_requests, dedup_mix_specs, drive_slots,
                        drive_tenants)

from repro.core.primes import CacheLevel
from repro.serving.dedup import (DEDUP_COUNTERS, DedupElasticShardedPagedKVCache,
                                 DedupOracle, DedupShardedPagedKVCache,
                                 DedupVectorizedPagedKVCache)
from repro.serving.kv_cache import PARITY_COUNTERS, PagedKVCache
from repro.serving.slots import SlotMachine, SlotOracle
from repro.tenancy.qos import (TenantedPagedKVCache,
                               refcount_weighted_shares)

# --------------------------------------------------------------------------- #
# helpers                                                                     #
# --------------------------------------------------------------------------- #


def _assert_dedup_parity(oracle, kv, name):
    for f in DEDUP_COUNTERS:
        assert getattr(kv.stats, f) == getattr(oracle.stats, f), (name, f)
    assert list(kv.hbm.items()) == list(oracle.hbm.items()), name
    assert kv.host == oracle.host, name
    assert kv.prefetch_log == oracle.prefetch_log, name
    assert kv.dedup_state() == oracle.dedup_state(), name
    assert np.allclose(kv.charged_shares(), oracle.charged_shares()), name
    T = oracle.qos_config.n_tenants
    for t in range(T):
        for f in PARITY_COUNTERS:
            assert getattr(kv.qos.tenant_stats[t], f) \
                == getattr(oracle.qos.tenant_stats[t], f), (name, t, f)
        assert kv.qos.tenant_logs[t] == oracle.qos.tenant_logs[t], (name, t)
        assert kv.qos.occupancy[t] == oracle.qos.occupancy[t], (name, t)
    assert kv.cross_tenant_prefetches() == 0, name


def _check_invariants(kv):
    """Step-boundary invariants of one dedup cache (any flavor)."""
    kv.namespace.assert_isolated(kv.registry)
    q = kv.qos
    assert 0 <= q.shared_occupancy <= q.shared_quota
    total = 0
    for pid, per_tenant in kv._tenant_refs.items():
        r = kv.ref_of(pid)
        assert r == sum(per_tenant.values()) > 0
        assert all(v > 0 for v in per_tenant.values())
        total += r
        # every refcounted page really lives in the shared namespace
        p = kv.assigner.prime_of(pid)
        assert p is not None
        assert kv.namespace.tenant_of_value(p) == kv.shared_part, pid
    assert total == sum(len(v) for v in kv._req_shared.values())
    for rid, pids in kv._req_shared.items():
        assert kv.dedup_prefix[rid] == len(pids)
        # shared pages form the chain's leading run (cumulative keys)
        assert list(kv.chains[rid][:len(pids)]) == pids


def _differential(spec: TenantMixSpec, hbm: int, budget: int,
                  shards=(), elastic=False, max_bits: int = 62) -> None:
    T = spec.n_tenants
    ops = build_tenant_requests(spec)
    caches = {
        "scalar": DedupOracle(hbm_pages=hbm, page_size=4,
                              prefetch_budget=budget, qos=T,
                              max_bits=max_bits),
        "vec": DedupVectorizedPagedKVCache(hbm_pages=hbm, page_size=4,
                                           prefetch_budget=budget, qos=T,
                                           max_bits=max_bits),
    }
    for n in shards:
        caches[f"shard{n}"] = DedupShardedPagedKVCache(
            hbm_pages=hbm, page_size=4, prefetch_budget=budget,
            n_shards=n, qos=T, max_bits=max_bits)
    if elastic:
        caches["elastic"] = DedupElasticShardedPagedKVCache(
            hbm_pages=hbm, page_size=4, prefetch_budget=budget,
            n_shards=2, qos=T, max_bits=max_bits)

    tiers = {name: drive_tenants(kv, ops,
                                 step_hook=_check_invariants
                                 if name in ("scalar", "vec") else None)
             for name, kv in caches.items()}
    oracle = caches["scalar"]
    for name, kv in caches.items():
        if name == "scalar":
            continue
        assert tiers[name] == tiers["scalar"], name
        _assert_dedup_parity(oracle, kv, name)
    for n in shards:
        kv = caches[f"shard{n}"]
        assert (kv.aggregate_shard_stats().parity_tuple()
                == kv.stats.parity_tuple())
    return caches


# --------------------------------------------------------------------------- #
# differential parity: oracle == vec == sharded == elastic                    #
# --------------------------------------------------------------------------- #

@given(spec=dedup_mix_specs(),
       hbm=st.sampled_from([6, 9, 24]),
       budget=st.integers(min_value=0, max_value=4))
@settings(max_examples=8, deadline=None)
def test_dedup_differential_fuzz_property(spec, hbm, budget):
    """Any drawn shared-prompt tenant mix: the oracle and the
    vectorized dedup cache agree bit-for-bit on every DEDUP counter,
    tier, LRU order, prefetch log, refcount map, and charged share —
    and the isolation theorem plus the refcount invariants hold after
    every single op."""
    _differential(spec, hbm, budget)


# deterministic pinned cases: the edge paths stay covered when
# hypothesis is not installed (tier-1 must not lose this coverage)
_PINNED = [
    # baseline shared-prompt mix, generous quota
    (TenantMixSpec(seed=2, n_tenants=2, n_requests=10, n_touches=110,
                   cross_prefix=True), 24, 3),
    # tight HBM: 2 shared slots, 1-2 private pages per tenant
    (TenantMixSpec(seed=4, n_tenants=4, n_requests=12, n_touches=100,
                   cross_prefix=True), 6, 2),
    # hot tenant hammering shared content + releases
    (TenantMixSpec(seed=6, n_tenants=2, n_requests=12, n_touches=130,
                   cross_prefix=True, hot_tenant=True), 9, 2),
    # scanner tenant sweeping whole chains across the COW boundary
    (TenantMixSpec(seed=8, n_tenants=3, n_requests=10, n_touches=90,
                   cross_prefix=True, scanner_tenant=True), 9, 3),
    # zero prefetch budget (pure LRU) + no releases (refs only grow)
    (TenantMixSpec(seed=10, n_tenants=2, n_requests=9, n_touches=80,
                   cross_prefix=True, release=False), 8, 0),
]
_PIN_IDS = ["baseline", "tight-quota", "hot-tenant", "scanner-cow",
            "no-budget-no-release"]


@pytest.mark.parametrize("spec,hbm,budget", _PINNED, ids=_PIN_IDS)
def test_dedup_differential_pinned(spec, hbm, budget):
    _differential(spec, hbm, budget)


@pytest.mark.parametrize("spec,hbm,budget", [_PINNED[0], _PINNED[3]],
                         ids=["baseline", "scanner-cow"])
def test_dedup_composes_with_sharded_and_elastic(spec, hbm, budget):
    """Dedup x mesh-sharded (1 and 2 shards) and x elastic: shard
    ownership, tenant isolation, and the shared namespace are three
    independent pure functions of the prime value, so parity and
    per-shard aggregation survive their composition (runs under
    shard_map on the forced-2-device CI mesh)."""
    _differential(spec, hbm, budget, shards=(1, 2), elastic=True)


def test_dedup_elastic_chaos_mid_run_keeps_parity():
    """resize / fail_shard / recover_shard mid-workload move shard
    striping only — the dedup twins stay bit-exact through them."""
    spec, hbm, budget = _PINNED[2]
    ops = build_tenant_requests(spec)
    a = DedupOracle(hbm_pages=hbm, page_size=4, prefetch_budget=budget,
                    qos=spec.n_tenants)
    b = DedupElasticShardedPagedKVCache(hbm_pages=hbm, page_size=4,
                                        prefetch_budget=budget,
                                        qos=spec.n_tenants)
    third = len(ops) // 3
    schedule = {third: [("resize", 3)],
                2 * third: [("kill", 1), ("recover", 1)]}

    def fire(kv, ev):
        if ev[0] == "resize":
            kv.resize(ev[1])
        elif ev[0] == "kill":
            kv.fail_shard(ev[1])
        else:
            kv.recover_shard(ev[1])

    ta = drive_tenants(a, ops)
    tb = drive_tenants(b, ops, schedule=schedule, on_event=fire,
                       step_hook=_check_invariants)
    assert ta == tb
    _assert_dedup_parity(a, b, "elastic-chaos")


def test_wide_dedup_composes():
    """Dedup over a wide (max_bits=128) registry: the admission gcd
    probes route through the multi-limb machinery and parity holds."""
    spec, hbm, budget = _PINNED[0]
    caches = _differential(spec, hbm, budget, max_bits=128)
    assert caches["scalar"].dedup_probes > 0
    assert caches["scalar"].dedup_state() == caches["vec"].dedup_state()


# --------------------------------------------------------------------------- #
# refcount lifecycle fuzz                                                     #
# --------------------------------------------------------------------------- #

def _lifecycle_drive(kv, ops):
    """Replay ops asserting the eviction-protection invariant at every
    boundary: a shared page that left HBM residency must have been
    unreferenced at the previous boundary — unless this very op dropped
    its references first (release / re-register)."""
    live = []
    prev = {}                     # resident shared pid -> ref at boundary
    composites_before = set()
    for op in ops:
        kind = op[0]
        dropped = set()
        if kind == "register":
            _, rid, tenant, tokens = op
            if rid in kv.chains:
                dropped = set(kv._req_shared.get(rid, ()))
            cow_before = kv.stats.cow_copies
            kv.register_request(rid, list(tokens), tenant=tenant)
            live.append(rid)
            # COW never rewrites: registration only ADDS composites —
            # except the deferred age-out flush at its entry, which may
            # purge composites of RECYCLED primes (each lost composite
            # must contain an aged prime; see kv.dedup_aged)
            now = set(kv.registry._by_composite)
            aged_primes = {p for _, p in kv.dedup_aged if p > 0}
            for c in composites_before - now:
                assert any(c % p == 0 for p in aged_primes), \
                    "COW must not rewrite live composites"
            composites_before = now
            assert kv.stats.cow_copies >= cow_before
        elif kind == "touch":
            _, a, b = op
            if live:
                rid = live[a % len(live)]
                chain = kv.chains.get(rid) or ()
                if chain:
                    kv.touch(rid, b % len(chain))
        elif kind == "sweep":
            if live:
                rid = live[op[1] % len(live)]
                chain = kv.chains.get(rid) or ()
                if chain:
                    kv.touch_batch([(rid, j) for j in range(len(chain))])
        elif kind == "release":
            if live:
                rid = live.pop(0)
                dropped = set(kv._req_shared.get(rid, ()))
                kv.release_request(rid)
        for pid, r in prev.items():
            if r > 0 and not kv._resident(pid) and pid not in dropped:
                raise AssertionError(
                    f"shared page {pid} evicted while referenced (ref={r})")
        prev = {pid: kv.ref_of(pid) for pid in kv._tenant_refs
                if kv._resident(pid)}
        _check_invariants(kv)


@given(spec=dedup_mix_specs(), hbm=st.sampled_from([6, 9, 16]))
@settings(max_examples=6, deadline=None)
def test_refcount_lifecycle_fuzz_property(spec, hbm):
    for cls in (DedupOracle, DedupVectorizedPagedKVCache):
        kv = cls(hbm_pages=hbm, page_size=4, prefetch_budget=2,
                 qos=spec.n_tenants)
        _lifecycle_drive(kv, build_tenant_requests(spec))


@pytest.mark.parametrize("spec,hbm,budget", _PINNED, ids=_PIN_IDS)
def test_refcount_lifecycle_pinned(spec, hbm, budget):
    for cls in (DedupOracle, DedupVectorizedPagedKVCache):
        kv = cls(hbm_pages=hbm, page_size=4, prefetch_budget=budget,
                 qos=spec.n_tenants)
        _lifecycle_drive(kv, build_tenant_requests(spec))


def test_referenced_shared_pages_are_pinned():
    """Shared quota pinned full by referenced pages: inserts degrade to
    host placement; releasing the references makes the pages evictable
    again — identically in both twins."""
    for cls in (DedupOracle, DedupVectorizedPagedKVCache):
        kv = cls(hbm_pages=9, page_size=2, prefetch_budget=0, qos=2)
        assert kv.qos_config.shared_quota == 3
        prompt = list(range(10))                 # 5 pages of prefix
        kv.register_request(0, prompt + [100, 101], tenant=0)
        kv.register_request(1, prompt + [200, 201], tenant=1)  # promote 5
        shared = kv._req_shared[1]
        assert len(shared) == 5 and kv.stats.dedup_promotions == 5
        # touch the whole shared run: only 3 fit, the rest stay host
        kv.touch_batch([(1, j) for j in range(5)])
        resident = [pid for pid in shared if kv._resident(pid)]
        assert len(resident) == 3
        assert kv.qos.shared_occupancy == 3
        # every resident shared page is referenced -> pinned: re-touch
        # of a host-resident shared page cannot displace them
        host_shared = [pid for pid in shared if not kv._resident(pid)]
        kv.touch_batch([(1, shared.index(host_shared[0]))])
        assert [pid for pid in shared if kv._resident(pid)] == resident
        # drop every reference: the old shared pages become evictable,
        # so NEW shared content can claim their slots
        kv.release_request(0)
        kv.release_request(1)
        fresh = [p + 500 for p in prompt]
        kv.register_request(2, fresh + [300, 301], tenant=0)
        kv.register_request(3, fresh + [400, 401], tenant=1)  # promote
        ev0 = kv.stats.evictions
        kv.touch_batch([(3, j) for j in range(5)])
        assert kv.stats.evictions > ev0
        assert kv.qos.shared_occupancy == 3


def test_zero_ref_shared_page_ages_out_and_recycles_prime():
    """PR 9 leak regression: evicting a zero-ref shared page used to
    leave its ``_global_content`` entry and prime alive forever — the
    content map grew without bound and later registrations could dedup
    onto the dead page.  Now the eviction ages the page out of the
    content map immediately, and the NEXT registration flushes the
    deferred prime release (the registry is quiescent there) — with the
    (pid, prime) audit trail in ``dedup_aged``, identically in both
    twins."""
    from repro.obs import EV_AGE_OUT, Observability

    states = []
    for cls in (DedupOracle, DedupVectorizedPagedKVCache):
        kv = cls(hbm_pages=9, page_size=2, prefetch_budget=0, qos=2)
        obs = Observability()
        kv.obs = obs
        prompt = list(range(10))                 # 5 pages of prefix
        kv.register_request(0, prompt + [100, 101], tenant=0)
        kv.register_request(1, prompt + [200, 201], tenant=1)  # promote
        shared = list(kv._req_shared[1])
        keys_before = len(kv._global_content)
        kv.touch_batch([(1, j) for j in range(5)])
        kv.release_request(0)
        kv.release_request(1)                    # refs -> 0, still cached
        # new shared content streams through the 3-slot shared quota:
        # every eviction of a zero-ref page must age it out
        fresh = [p + 500 for p in prompt]
        kv.register_request(2, fresh + [300, 301], tenant=0)
        kv.register_request(3, fresh + [400, 401], tenant=1)
        kv.touch_batch([(3, j) for j in range(5)])
        aged = dict(kv.dedup_aged)
        assert aged, "evicting zero-ref shared pages must age them out"
        for pid, prime in aged.items():
            assert pid in shared and prime > 0
            assert not kv._resident(pid)
            assert pid not in kv.host            # no host demotion: dead
            assert pid not in kv._shared_users
        # the aged pids are unreachable through the content map
        assert not set(aged) & set(kv._global_content.values())
        assert len(kv._global_content) < keys_before + len(kv.chains[2]) \
            + len(kv.chains[3])                  # it shrank, not just grew
        assert [e.page for e in obs.trace.events()
                if e.kind == EV_AGE_OUT] == [pid for pid, _ in kv.dedup_aged]
        # primes are still assigned until the deferred flush...
        assert kv._aged_pending
        assert all(kv.assigner.prime_of(pid) is not None for pid in aged)
        # ...which the next registration performs: primes recycled, and
        # re-registering the ORIGINAL tokens gets fresh pages (no
        # aliasing onto the dead pids)
        kv.register_request(4, prompt + [999], tenant=0)
        assert not kv._aged_pending
        for pid in aged:
            assert kv.assigner.prime_of(pid) is None
        assert not set(kv.chains[4]) & set(aged)
        kv.namespace.assert_isolated(kv.registry)
        states.append((sorted(kv.dedup_aged), kv.dedup_state(),
                       kv.stats.parity_tuple()))
    assert states[0] == states[1]                # twin parity incl. aging


def test_cow_allocates_fresh_prime_composites_untouched():
    """First divergence off a shared prefix: a fresh PRIVATE page with
    a fresh prime from the requester's own namespace; the shared page's
    prime and every pre-existing composite are unchanged."""
    kv = DedupOracle(hbm_pages=24, page_size=2, prefetch_budget=2, qos=3)
    prefix = [1, 2, 3, 4]
    kv.register_request(0, prefix + [10, 11], tenant=0)
    kv.register_request(1, prefix + [20, 21], tenant=1)   # promotes prefix
    shared = list(kv._req_shared[1])
    assert len(shared) == 2
    shared_primes = {pid: kv.assigner.prime_of(pid) for pid in shared}
    comps_before = set(kv.registry._by_composite)
    cow_before = kv.stats.cow_copies

    kv.register_request(2, prefix + [30, 31], tenant=2)   # COW at page 3
    assert kv.stats.cow_copies == cow_before + 1
    chain = kv.chains[2]
    assert list(chain[:2]) == shared                       # shared run
    cow_page = chain[2]
    p = kv.assigner.prime_of(cow_page)
    # fresh prime, from tenant 2's OWN namespace part (not shared)
    assert p not in shared_primes.values()
    assert kv.namespace.tenant_of_value(p) == 2
    # shared pages keep their primes; old composites all still live
    assert {pid: kv.assigner.prime_of(pid) for pid in shared} \
        == shared_primes
    assert comps_before <= set(kv.registry._by_composite)
    assert kv.namespace.check_isolation(kv.registry, pairwise_gcd=True).ok


def test_charged_shares_refcount_weighted():
    """The HBM-bytes/user metric: each tenant is charged its private
    occupancy plus its refcount fraction of every resident shared
    page (hand-computed expectation)."""
    assert np.allclose(
        refcount_weighted_shares([2, 1], [{0: 1, 1: 1}, {1: 3}]),
        [2.5, 2.5])
    kv = DedupVectorizedPagedKVCache(hbm_pages=12, page_size=2,
                                     prefetch_budget=2, qos=2)
    kv.register_request(0, [1, 2, 3, 4, 50], tenant=0)
    kv.register_request(1, [1, 2, 3, 4, 60], tenant=1)
    kv.touch_batch([(0, j) for j in range(3)]
                   + [(1, j) for j in range(3)])
    shares = kv.charged_shares()
    occ = kv.qos.occupancy
    resident_refs = kv.shared_page_refs()
    want = refcount_weighted_shares(occ, resident_refs)
    assert np.allclose(shares, want)
    # the donor (tenant 0) kept private pages; only tenant 1 references
    # the promoted shared pages, so it bears their full charge
    n_sh = len(resident_refs)
    assert n_sh > 0
    assert all(set(r) == {1} for r in resident_refs)
    assert np.allclose(shares, [occ[0], occ[1] + n_sh])
    # a second referencing tenant splits the charge refcount-weighted
    kv.register_request(2, [1, 2, 3, 4, 70], tenant=0)
    kv.touch_batch([(2, j) for j in range(3)])
    occ2 = kv.qos.occupancy
    both = [r for r in kv.shared_page_refs() if set(r) == {0, 1}]
    assert both and all(r == {0: 1, 1: 1} for r in both)
    assert np.allclose(
        kv.charged_shares(),
        refcount_weighted_shares(occ2, kv.shared_page_refs()))


# --------------------------------------------------------------------------- #
# content-key collision regression (the PR's headline bugfix)                 #
# --------------------------------------------------------------------------- #

def test_content_key_hash_collision_regression():
    """CPython hashes -1 and -2 to the same value, so the token tuples
    ``(-1,)`` and ``(-2,)`` collide under ``hash``.  The content maps
    used to key on ``hash(content_key)`` and aliased such prefixes to
    ONE page — distinct content must get distinct pages, in the plain,
    tenanted, and dedup caches alike."""
    assert hash((-1,)) == hash((-2,))            # the collision vector

    kv = PagedKVCache(hbm_pages=8, page_size=1)
    kv.register_request(0, [-1])
    kv.register_request(1, [-2])
    assert kv.chains[0][0] != kv.chains[1][0]
    assert kv.shared_prefix(0, 1) == []
    assert kv.stats.shared_prefix_pages == 0

    t = TenantedPagedKVCache(hbm_pages=8, page_size=1, qos=2)
    t.register_request(0, [-1], tenant=0)
    t.register_request(1, [-2], tenant=0)        # same tenant, same map
    assert t.chains[0][0] != t.chains[1][0]
    assert t.stats.shared_prefix_pages == 0

    d = DedupOracle(hbm_pages=9, page_size=1, qos=2)
    d.register_request(0, [-1], tenant=0)
    d.register_request(1, [-2], tenant=1)        # global map probe
    assert d.chains[0][0] != d.chains[1][0]
    assert d.stats.dedup_hits == d.stats.dedup_promotions == 0
    # and the true-duplicate still dedups: same content, third request
    d.register_request(2, [-1], tenant=1)
    assert d.stats.dedup_promotions == 1


# --------------------------------------------------------------------------- #
# composition: SlotMachine continuous batching + ServingEngine plumbing       #
# --------------------------------------------------------------------------- #

def _slot_pair(kv_m, kv_o, spec, **kw):
    base = dict(max_batch=4, page_size=4, hbm_pages=27, prefetch_budget=2,
                reread_window=2, prefill_tokens=12, preempt_wait=3,
                tenants=2, dedup=True)
    base.update(kw)
    arrivals = build_poisson_arrivals(spec)
    m = SlotMachine(kv=kv_m, **base)
    o = SlotOracle(kv=kv_o, **base)
    drive_slots(m, arrivals)
    drive_slots(o, arrivals)
    return m, o


@pytest.mark.parametrize("kv_m,kv_o", [("vec", "scalar"),
                                       ("sharded", "vec"),
                                       ("elastic", "scalar")])
def test_slot_machine_dedup_parity(kv_m, kv_o):
    """SlotMachine x dedup across backends: bit-exact tier logs,
    DEDUP counters, dedup twin state, per-request timings — including
    the admission prefill skip over the shared run."""
    spec = ArrivalSpec(seed=5, n_requests=18, rate=1.5, max_prompt=24,
                       max_new=8, shared_pool=16, n_tenants=2)
    m, o = _slot_pair(kv_m, kv_o, spec)
    assert m.tier_log == o.tier_log
    for f in DEDUP_COUNTERS:
        assert getattr(m.pages.stats, f) == getattr(o.pages.stats, f), f
    assert m.pages.dedup_state() == o.pages.dedup_state()
    assert (m.ticks, m.preemptions, m.resumes) \
        == (o.ticks, o.preemptions, o.resumes)
    for rm, ro in zip(m.requests, o.requests):
        assert rm.state == ro.state == "done"
        assert (rm.first_tick, rm.done_tick, rm.ttft(), rm.tpot()) \
            == (ro.first_tick, ro.done_tick, ro.ttft(), ro.tpot())
    assert m.pages.stats.dedup_hits > 0
    assert m.pages.cross_tenant_prefetches() == 0


def test_slot_machine_dedup_skips_shared_prefill():
    """The admission prefill skip is real: with dedup on, a request
    whose whole prompt is an already-shared prefix finishes its prefill
    in strictly fewer ticks than the no-dedup engine needs."""
    shared = list(range(24))
    arrivals = [(0, tuple(shared + [100 + i]), 2, i % 2)
                for i in range(4)]
    ttft = {}
    for dedup in (False, True):
        m = SlotMachine(max_batch=4, page_size=4, hbm_pages=27,
                        prefetch_budget=2, prefill_tokens=8,
                        tenants=2, dedup=dedup)
        drive_slots(m, arrivals)
        ttft[dedup] = [r.ttft() for r in m.requests]
        if dedup:
            assert m.pages.stats.dedup_hits > 0
    # first admissions pay full prefill either way; the dedup'd
    # followers skip the shared run and must strictly beat no-dedup
    assert sum(ttft[True]) < sum(ttft[False])


def test_engine_dedup_plumbing_and_validation():
    from repro.serving.engine import ServingEngine, make_kv_backend

    with pytest.raises(ValueError, match="dedup"):
        make_kv_backend("vec", hbm_pages=8, page_size=4,
                        prefetch_budget=2, dedup=True)
    with pytest.raises(ValueError):
        make_kv_backend("nope", hbm_pages=8, page_size=4,
                        prefetch_budget=2, tenants=2, dedup=True)
    eng = ServingEngine(kv="vec", hbm_pages=12, page_size=4,
                        tenants=2, dedup=True)
    prompt = list(range(12))
    eng.submit(prompt + [50], max_new_tokens=2, tenant=0)
    eng.submit(prompt + [60], max_new_tokens=2, tenant=1)
    eng.run_until_idle()
    kvc = eng.pages
    assert kvc.stats.dedup_promotions > 0
    assert kvc.namespace.check_isolation(kvc.registry,
                                         pairwise_gcd=True).ok
