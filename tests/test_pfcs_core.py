"""PFCS core: primes, factorization, composites — incl. the paper's
Theorem 1 (zero false positives) as a machine-checked property."""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import (CacheLevel, CompositeRegistry, Factorizer,
                        HierarchicalPrimeAllocator, PrimeAssigner,
                        encode_relationship, is_prime, segmented_sieve,
                        sieve_primes, spf_table)


# --------------------------------------------------------------------------- #
# primes                                                                      #
# --------------------------------------------------------------------------- #

def test_sieve_small():
    assert list(sieve_primes(30)) == [2, 3, 5, 7, 11, 13, 17, 19, 23, 29]


def test_sieve_counts():
    assert len(sieve_primes(1_000)) == 168
    assert len(sieve_primes(100_000)) == 9592


def test_spf_table_recovers_factorization():
    spf = spf_table(10_000)
    for n in [2, 4, 60, 97, 9991, 9999]:
        out = []
        m = n
        while m > 1:
            p = int(spf[m])
            out.append(p)
            m //= p
        prod = 1
        for p in out:
            prod *= p
        assert prod == n
        assert all(is_prime(p) for p in out)


def test_segmented_sieve_matches_full():
    full = sieve_primes(5_000)
    seg = segmented_sieve(1_000, 5_001)
    assert list(seg) == [int(p) for p in full if p >= 1_000]


@given(st.integers(min_value=2, max_value=10**6))
@settings(max_examples=200, deadline=None)
def test_is_prime_agrees_with_trial_division(n):
    ref = all(n % d for d in range(2, int(n**0.5) + 1))
    assert is_prime(n) == ref


def test_pool_allocation_ascending_and_recycle():
    alloc = HierarchicalPrimeAllocator()
    pool = alloc.pool(CacheLevel.L1)
    ps = [pool.allocate() for _ in range(10)]
    assert ps == sorted(ps) and ps[0] == 2
    pool.free(ps[3])
    assert pool.allocate() == ps[3]  # freed primes are reused first


def test_l1_pool_exhausts_at_168():
    alloc = HierarchicalPrimeAllocator()
    pool = alloc.pool(CacheLevel.L1)
    got = [pool.allocate() for _ in range(168)]
    assert all(p is not None for p in got)
    assert pool.allocate() is None  # bounded pool is dry


def test_mem_pool_is_unbounded():
    alloc = HierarchicalPrimeAllocator()
    pool = alloc.pool(CacheLevel.MEM)
    ps = [pool.allocate() for _ in range(5000)]
    assert all(p >= 1_000_003 for p in ps)
    assert len(set(ps)) == 5000


# --------------------------------------------------------------------------- #
# factorization (Algorithm 2)                                                 #
# --------------------------------------------------------------------------- #

def test_factorize_stages():
    f = Factorizer()
    assert f.factorize(143) == (11, 13)            # SPF table
    assert f.stats.table_hits == 1
    big = 1_000_003 * 1_000_033                    # Pollard rho territory
    assert f.factorize(big) == (1_000_003, 1_000_033)
    assert f.factorize(big) == (1_000_003, 1_000_033)  # cache hit
    assert f.stats.cache_hits >= 1


def test_factorize_with_multiplicity():
    f = Factorizer()
    assert f.factorize(8) == (2, 2, 2)
    assert f.factorize(2**3 * 3**2 * 97) == (2, 2, 2, 3, 3, 97)


@given(st.lists(st.sampled_from([2, 3, 5, 7, 11, 13, 1009, 99991,
                                 100_003, 999_983]),
                min_size=1, max_size=4, unique=True))
@settings(max_examples=100, deadline=None)
def test_factorize_roundtrip(primes):
    f = Factorizer()
    c = 1
    for p in primes:
        c *= p
    assert f.distinct_factors(c) == tuple(sorted(primes))


# --------------------------------------------------------------------------- #
# composites — Theorem 1                                                      #
# --------------------------------------------------------------------------- #

@given(st.sets(st.sampled_from(list(range(3, 600, 2))), min_size=2, max_size=8))
@settings(max_examples=150, deadline=None)
def test_zero_false_positives(odd_ids):
    """Theorem 1: decoding a relationship's composites recovers exactly the
    registered primes — never a superset, never a subset."""
    primes = sieve_primes(10_000)
    reg = CompositeRegistry()
    chosen = frozenset(int(primes[i]) for i in odd_ids)
    if len(chosen) < 2:
        return
    rel = reg.register(chosen)
    recovered = set()
    for c in rel.composites:
        recovered |= set(reg.decode(c))
    assert recovered == set(chosen)


def test_divisibility_scan_exact():
    reg = CompositeRegistry()
    r1 = reg.register({11, 13})
    r2 = reg.register({13, 17})
    r3 = reg.register({19, 23})
    hits = reg.containing(13)
    assert {r.rel_id for r in hits} == {r1.rel_id, r2.rel_id}
    assert reg.related_primes(13) == {11, 17}
    assert reg.related_primes(19) == {23}


def test_encode_relationship_chunks_overflow():
    big_primes = [1_000_003, 1_000_033, 1_000_037, 1_000_039,
                  1_000_081, 1_000_099, 1_000_117, 1_000_121]
    chunks = encode_relationship(big_primes, max_bits=62)
    assert len(chunks) > 1
    prod = 1
    for c in chunks:
        assert c < 2**62
        prod *= c
    expect = 1
    for p in big_primes:
        expect *= p
    assert prod == expect


def test_composite_overflow_is_detected_never_silent():
    """int64-overflow management (ROADMAP item 2): a deep relationship
    chain whose product wraps 2**63 must be chunked or rejected — never
    silently corrupted into a wrapped composite.

    Three layers of defense, each asserted:
      1. ``encode_relationship`` rejects any single prime that cannot
         fit the chunk budget at all;
      2. a deep chain registers as multiple exact chunks whose int64
         kernel view stays positive (no wraparound) and factorizes back
         to exactly the member primes (Theorem 1 survives the chunking);
      3. a registry misconfigured so chunks could exceed the signed
         int64 kernel word is rejected at construction.
    """
    # (1) an un-representable prime raises, both standalone and in a chain
    huge = (1 << 62) + 57                   # any value >= 2**62 works here
    with pytest.raises(ValueError):
        encode_relationship([huge], max_bits=62)
    with pytest.raises(ValueError):
        encode_relationship([11, huge], max_bits=62)

    # (2) deep chain: 40 primes near 2**20 -> product ~2**800, far past
    # int64; registration must stay exact via chunking
    reg = CompositeRegistry()
    primes = [p for p in range(1_048_583, 1_050_000) if is_prime(p)][:40]
    assert len(primes) == 40
    rel = reg.register(primes)
    assert len(rel.composites) > 1          # chunked, not wrapped
    arr = reg.composites_array()
    assert arr.dtype == np.int64
    assert (arr > 0).all()                  # a wrap would go negative
    prod = 1
    for c in rel.composites:
        assert 1 < c < 2**62
        prod *= c
    expect = 1
    for p in primes:
        expect *= p
    assert prod == expect                   # bit-exact over the chunks
    # factorization recovers the exact member set from the chunks
    members = set()
    for c in rel.composites:
        members |= set(reg.decode(int(c)))
    assert members == set(primes)
    # divisibility scan still finds the chain through any member
    assert reg.related_primes(primes[0]) == set(primes) - {primes[0]}

    # (3) degenerate chunk budgets are construction errors; widths past
    # one int64 word — which PR 6 rejected outright — now construct a
    # multi-limb wide registry ("represent, never raise", DESIGN.md §11)
    for bad in (1, 0, -5, 4097):
        with pytest.raises(ValueError):
            CompositeRegistry(max_bits=bad)
    assert CompositeRegistry(max_bits=63).max_bits == 63   # boundary ok
    for wide_bits in (64, 70, 1024):       # formerly ValueError traces
        wr = CompositeRegistry(max_bits=wide_bits)
        assert wr.wide
        rel_w = wr.register(primes)        # the same ~2**800 deep chain
        assert len(rel_w.composites) <= len(rel.composites)
        prod_w = 1
        for c in rel_w.composites:
            assert 1 < c < 2**wide_bits
            prod_w *= c
        assert prod_w == expect            # bit-exact at every width
        members_w = set()
        for c in rel_w.composites:
            # a wide chunk can be hundreds of bits — give the Pollard
            # tail a real budget instead of the 50ms per-access default
            members_w |= set(wr.factorizer.distinct_factors(
                int(c), time_budget_s=10.0))
        assert members_w == set(primes)
        with pytest.raises(OverflowError):
            wr.composites_array()          # int64 view refuses to wrap
    # a 1024-bit budget holds the whole chain in ONE exact chunk
    assert len(CompositeRegistry(max_bits=1024).register(primes)
               .composites) == 1


def test_encode_relationship_budget_boundary_edges():
    """ISSUE 8 satellite: the chunk boundary is inclusive on the value
    side, exclusive on the budget — a chunk product of exactly
    ``2**max_bits - 1`` is accepted, a member of exactly ``2**max_bits``
    is rejected with the existing message."""
    # 2**11 - 1 = 2047 = 23 * 89: the product lands EXACTLY on the
    # largest representable value and must stay one chunk
    assert encode_relationship([89, 23], max_bits=11) == [2047]
    # a Mersenne prime IS the largest representable value: accepted
    assert encode_relationship([8191], max_bits=13) == [8191]
    # one past the edge: 2**max_bits itself is rejected, with the same
    # message the PR 6 guard established
    with pytest.raises(ValueError,
                       match=r"exceeds 11-bit composite budget"):
        encode_relationship([2048], max_bits=11)
    with pytest.raises(ValueError,
                       match=r"exceeds 62-bit composite budget"):
        encode_relationship([1 << 62], max_bits=62)
    # product one past the edge splits instead of overflowing:
    # 3 * 683 = 2049 = 2**11 + 1
    assert encode_relationship([3, 683], max_bits=11) == [3, 683]


@given(st.lists(st.sampled_from([2, 3, 5, 7, 11, 13, 10007, 10009,
                                 1_000_003, 1_000_033]),
                min_size=1, max_size=12),
       st.randoms(use_true_random=False))
@settings(max_examples=150, deadline=None)
def test_encode_relationship_canonical_for_multisets(ms, rnd):
    """ISSUE 8 satellite: chunking is canonical in ONE place — the same
    multiset (duplicates included) produces the same chunk tuple
    regardless of caller order, at narrow and wide widths."""
    shuffled = list(ms)
    rnd.shuffle(shuffled)
    for mb in (62, 128):
        a = encode_relationship(ms, max_bits=mb)
        b = encode_relationship(shuffled, max_bits=mb)
        assert a == b
        prod = 1
        for c in a:
            prod *= c
        expect = 1
        for p in ms:
            expect *= p
        assert prod == expect              # duplicates all survive


def test_encode_relationship_canonical_deterministic():
    """Hypothesis-free pin of the canonical-chunking property (the
    tier-1 suite runs without dev deps): shuffled duplicate-prime
    multisets produce identical chunk tuples."""
    import random
    ms = [1_000_003, 2, 1_000_003, 999_983, 7, 7, 10007, 1_000_033,
          999_983, 3]
    rnd = random.Random(8)
    for mb in (62, 96, 1024):
        want = encode_relationship(ms, max_bits=mb)
        for _ in range(25):
            shuffled = list(ms)
            rnd.shuffle(shuffled)
            assert encode_relationship(shuffled, max_bits=mb) == want


def test_register_chunks_match_canonical_encoding():
    """``CompositeRegistry.register`` must not re-sort: its chunk tuple
    is exactly ``encode_relationship`` of the prime SET."""
    for mb in (62, 128):
        reg = CompositeRegistry(max_bits=mb)
        ps = {1_000_037, 11, 999_983, 10007}
        rel = reg.register(ps)
        assert list(rel.composites) == encode_relationship(ps, mb)


def test_drop_prime_purges_relationships():
    reg = CompositeRegistry()
    reg.register({11, 13})
    reg.register({11, 17})
    reg.register({19, 23})
    reg.drop_prime(11)
    assert len(reg) == 1
    assert reg.related_primes(13) == set()


def test_assigner_recycling_under_exhaustion():
    assigner = PrimeAssigner()
    # force many hot assignments into tiny L1 (168 primes)
    for i in range(200):
        assigner.tracker.record(i)
        assigner.tracker._freq[i] = 0.9  # hot -> L1-range selection
        p = assigner.assign(i, CacheLevel.L1)
        assert p is not None
    assert assigner.stats.recycle_events >= 1


# --------------------------------------------------------------------------- #
# prime-pool free / release audit (double-free + foreign-prime paths)         #
# --------------------------------------------------------------------------- #

def test_pool_double_free_is_noop():
    """A double-freed prime must NOT land on the free-list twice (two
    data elements sharing one prime would break unique decoding)."""
    alloc = HierarchicalPrimeAllocator()
    pool = alloc.pool(CacheLevel.L1)
    ps = [pool.allocate() for _ in range(4)]
    pool.free(ps[1])
    pool.free(ps[1])                    # double free: no-op
    assert pool.allocate() == ps[1]     # handed out once...
    nxt = pool.allocate()
    assert nxt != ps[1]                 # ...and only once
    assert pool.n_allocated == 5


def test_pool_foreign_and_unallocated_free_are_noops():
    alloc = HierarchicalPrimeAllocator()
    pool = alloc.pool(CacheLevel.L2)
    p = pool.allocate()
    before = (pool.n_allocated, len(pool._free))
    pool.free(5)           # foreign: out of the L2 value range entirely
    pool.free(1013)        # in range but never allocated here
    assert (pool.n_allocated, len(pool._free)) == before
    pool.free(p)
    assert pool.allocate() == p


def test_allocator_free_routes_to_owning_pool():
    """Freeing with a wrong level id used to leak the prime (the range
    guard made the mis-routed free a silent no-op, so the prime was
    never reusable); the allocator now routes by value ownership."""
    alloc = HierarchicalPrimeAllocator()
    p = alloc.allocate(CacheLevel.L2)
    alloc.free(CacheLevel.L1, p)        # wrong level on purpose
    assert alloc.allocate(CacheLevel.L2) == p   # reusable again
    # stats stay sane in the owning pool
    assert alloc.pool(CacheLevel.L1).n_allocated == 0


def test_assigner_release_idempotent_and_epoch():
    assigner = PrimeAssigner()
    p = assigner.assign("x", CacheLevel.L2)
    assert assigner.epoch == 0
    assigner.release("x", CacheLevel.L2)
    assert assigner.epoch == 1
    assert assigner.prime_of("x") is None
    assigner.release("x", CacheLevel.L2)        # double release: no-op
    assigner.release("never-seen", CacheLevel.L2)
    assert assigner.epoch == 1
    # the freed prime is reusable exactly once
    assert assigner.assign("y", CacheLevel.L2) == p
    assert assigner.assign("z", CacheLevel.L2) != p


# --------------------------------------------------------------------------- #
# batched (streamed) build — bit-identical to the per-element loop            #
# --------------------------------------------------------------------------- #

def _registry_state(reg):
    """Full observable registry state (dict orders included)."""
    return (
        reg._next_id, reg.version,
        list(reg._by_composite.items()),
        dict(reg._prime_degree),
        {rid: (r.rel_id, r.primes, r.composites, r.kind, r.weight)
         for rid, r in reg._by_id.items()},
    )


@pytest.mark.parametrize("max_bits", [62, 1024])
def test_batched_build_state_identity(max_bits):
    """``assign_many`` + ``register_many`` (the case_scale streamed
    build) must leave the assigner and registry in *bit-identical*
    state vs the scalar per-element loop — same primes in the same
    order, same relationship ids, same composite dict order, same
    ``version`` — in both narrow and wide (multi-limb) modes."""
    from repro.core.primes import CacheLevel as CL

    def build(batched):
        reg = CompositeRegistry(max_bits=max_bits)
        asg = PrimeAssigner(HierarchicalPrimeAllocator(), reg)
        n_chains, depth = 8, 12
        if batched:
            prime_of = asg.assign_many(range(n_chains * depth), CL.MEM)
        else:
            prime_of = [asg.assign(d, CL.MEM)
                        for d in range(n_chains * depth)]
        for c in range(n_chains):
            row = prime_of[c * depth:(c + 1) * depth]
            if batched:
                reg.register_many(zip(row, row[1:]), kind="chain")
            else:
                for a, b in zip(row, row[1:]):
                    reg.register((a, b), kind="chain")
            if c % 4 == 0:
                reg.register(row, kind="group")
        return reg, asg, prime_of

    r1, a1, p1 = build(False)
    r2, a2, p2 = build(True)
    assert p1 == p2
    assert _registry_state(r1) == _registry_state(r2)
    assert a1._data_to_prime == a2._data_to_prime
    assert a1._prime_to_data == a2._prime_to_data
    assert (a1.stats.assigned, a1.stats.reused) == \
           (a2.stats.assigned, a2.stats.reused)


def test_allocate_many_matches_scalar_sequence():
    from repro.core.primes import PrimePool

    s, b = PrimePool(level=0, lo=2, hi=997), PrimePool(level=0, lo=2, hi=997)
    assert [s.allocate() for _ in range(20)] == b.allocate_many(20)
    # free-list consumption is smallest-first in both paths
    for p in (7, 61, 13):
        s.free(p)
        b.free(p)
    assert [s.allocate() for _ in range(5)] == b.allocate_many(5)
    assert s._allocated == b._allocated
    assert sorted(s._free) == sorted(b._free)
    # bounded pool running dry: batched returns the scalar prefix
    sd, bd = PrimePool(level=0, lo=2, hi=29), PrimePool(level=0, lo=2, hi=29)
    scalar = [sd.allocate() for _ in range(20)]
    assert bd.allocate_many(20) == [p for p in scalar if p is not None]
    assert bd.allocate_many(3) == []
    assert bd.allocate_many(0) == []


def test_assign_many_mixed_warm_and_duplicates():
    """Warm elements and within-batch duplicates must break the bulk
    run and fall back to scalar ``assign`` at their original position,
    keeping allocation order (and stats) identical."""
    s = PrimeAssigner(registry=CompositeRegistry())
    b = PrimeAssigner(registry=CompositeRegistry())
    ds = ["a", "b", "c", "b", "d", "warm", "a", "e"]
    s.tracker.record("warm")
    b.tracker.record("warm")
    assert [s.assign(d, CacheLevel.L2) for d in ds] == \
        b.assign_many(ds, CacheLevel.L2)
    assert s._data_to_prime == b._data_to_prime
    assert (s.stats.assigned, s.stats.reused) == \
           (b.stats.assigned, b.stats.reused)


def test_register_many_error_parity_preserves_prefix():
    """A failing group mid-batch raises the canonical encoder error and
    leaves exactly the scalar loop's partial state (completed prefix
    registered, failing group not)."""
    groups = [(3, 5), (7, 11), (13, 1)]
    s, b = CompositeRegistry(), CompositeRegistry()
    with pytest.raises(ValueError, match="not a prime: 1") as e_scalar:
        for g in groups:
            s.register(g)
    with pytest.raises(ValueError, match="not a prime: 1") as e_batch:
        b.register_many(groups)
    assert str(e_scalar.value) == str(e_batch.value)
    assert _registry_state(s) == _registry_state(b)
    with pytest.raises(ValueError):
        b.register_many([(17,)])            # < 2 distinct elements
    # wide mode: oversized prime rejected with the canonical limb error
    w = CompositeRegistry(max_bits=128)
    with pytest.raises(ValueError, match="kernel limb word"):
        w.register_many([(3, (1 << 31) + 11)])
