"""End-to-end integration: training convergence, resume determinism,
hybrid prefill/decode consistency, engine batching invariants."""

import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.data.pipeline import ShardedLoader, SyntheticCorpus
from repro.models import build_model
from repro.training.checkpoint import CheckpointManager
from repro.training.train_loop import (TrainState, init_train_state,
                                       make_train_step)


def test_training_reduces_loss():
    """A few hundred steps on the synthetic corpus must reduce CE."""
    cfg = get_smoke("qwen2.5-3b").replace(vocab_size=259)
    model = build_model(cfg)
    corpus = SyntheticCorpus(vocab_size=259)
    loader = ShardedLoader(corpus, global_batch=8, seq_len=64)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, lr=1e-3, warmup=10,
                                   total_steps=150), donate_argnums=(0,))
    losses = []
    for i in range(150):
        batch = {k: jnp.asarray(v) for k, v in loader.batch_at(i).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    first = np.mean(losses[:10])
    last = np.mean(losses[-10:])
    assert last < first - 0.05, (first, last)


def test_checkpoint_resume_bit_identical(tmp_path):
    """Restart from a checkpoint reproduces the exact same next step
    (deterministic loader keyed on step + atomic checkpoint)."""
    cfg = get_smoke("gemma-2b").replace(vocab_size=259)
    model = build_model(cfg)
    corpus = SyntheticCorpus(vocab_size=259)
    loader = ShardedLoader(corpus, global_batch=4, seq_len=32)
    step = jax.jit(make_train_step(model, lr=1e-3, warmup=0, total_steps=50))
    mgr = CheckpointManager(tmp_path)

    state = init_train_state(model, jax.random.PRNGKey(1))
    for i in range(5):
        batch = {k: jnp.asarray(v) for k, v in loader.batch_at(i).items()}
        state, _ = step(state, batch)
    mgr.save(5, state)
    batch6 = {k: jnp.asarray(v) for k, v in loader.batch_at(5).items()}
    cont, m_cont = step(state, batch6)

    restored = mgr.restore(state, step=5)
    resumed, m_res = step(TrainState(*restored), batch6)
    assert float(m_cont["loss"]) == float(m_res["loss"])
    for a, b in zip(jax.tree.leaves(cont.params), jax.tree.leaves(resumed.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_zamba_decode_consistency():
    """Hybrid arch: feeding tokens one-by-one through decode reproduces
    the parallel train forward's final logits (SSD recurrence + shared
    attention KV both exercised)."""
    cfg = get_smoke("zamba2-7b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(2))
    B, S = 1, 32
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, size=(B, S)).astype(np.int32)
    full, _ = jax.jit(model.train_logits)(params, {"tokens": jnp.asarray(toks)})
    cache = model.init_cache(B, S + 2)
    dec = jax.jit(model.decode_step)
    logits = None
    for t in range(S):
        logits, cache = dec(params, {"tokens": jnp.asarray(toks[:, t:t + 1])},
                            cache)
    np.testing.assert_allclose(np.asarray(full[:, -1], np.float32),
                               np.asarray(logits[:, 0], np.float32),
                               rtol=2e-2, atol=2e-2)


def test_engine_requests_isolated():
    """Continuous batching: concurrent requests with different prompts get
    different generations (no cross-slot cache bleed)."""
    from repro.serving.engine import ServingEngine

    cfg = get_smoke("gemma-2b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(3))
    eng = ServingEngine(model, params, max_batch=2, max_seq=64, page_size=8)
    rng = np.random.default_rng(1)
    a = eng.submit(list(rng.integers(0, 500, size=12)), max_new_tokens=6)
    b = eng.submit(list(rng.integers(0, 500, size=12)), max_new_tokens=6)
    done = eng.run_until_idle()
    gens = {r.req_id: tuple(r.generated) for r in done}
    assert len(done) == 2
    assert gens[a] != gens[b]
