"""The paper's motivating example (§2.1) end to end: a join-heavy OLTP
workload where PFCS discovers FK relationships deterministically and
beats LRU/ARC/semantic caching on hit rate and modeled latency.

    PYTHONPATH=src python examples/pfcs_database_demo.py
"""

from repro.core import (db_join_trace, derive_table1_row, run_all_systems)

CAPS = (("L1", 64), ("L2", 256), ("L3", 2048))

trace = db_join_trace(n_orders=4000, n_customers=600, n_items=1200,
                      n_queries=20000)
print(f"workload: {trace.length} accesses over {trace.n_keys} rows, "
      f"{len(trace.relationships)} FK relationships "
      "(orders -> customers -> items)\n")

results = run_all_systems(trace, CAPS,
                          systems=("lru", "arc", "semantic", "pfcs"))
base = results["lru"]
print(f"{'system':10s} {'hit rate':>9s} {'lat. red.':>10s} "
      f"{'rel. accuracy':>14s}")
for name, stats in results.items():
    row = derive_table1_row(stats, base)
    acc = (f"{row['relationship_accuracy_pct']:.1f}%"
           if row["relationship_accuracy_pct"] is not None else "n/a")
    print(f"{name:10s} {row['hit_rate_pct']:8.1f}% "
          f"{row['latency_reduction_pct']:9.1f}% {acc:>14s}")

pfcs = results["pfcs"]
print(f"\nPFCS prefetches: {pfcs.prefetches_issued} issued, "
      f"{pfcs.prefetches_used} used before eviction, "
      f"precision {100*pfcs.prefetch_precision:.1f}% "
      "(zero false positives — Theorem 1)")
print(f"factorization stages: {pfcs.factor_ops}")
