"""PFCS quickstart: deterministic relationship discovery in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import PFCSCache, Factorizer

# ---------------------------------------------------------------- #
# 1. the core idea: relationships are composites of unique primes  #
# ---------------------------------------------------------------- #
f = Factorizer()
# customer_id=3291 -> prime 11, order_id=12847 -> prime 13 (paper §2.2)
composite = 11 * 13
print(f"composite {composite} factors back to {f.factorize(composite)}"
      " — exactly the related pair, zero false positives (Theorem 1)")

# ---------------------------------------------------------------- #
# 2. the cache system                                              #
# ---------------------------------------------------------------- #
cache = PFCSCache(capacities=(("L1", 8), ("L2", 32), ("L3", 128)))

# schema time: the database registers its FK relationships
cache.register_relationship(["order:12847", "customer:3291"], kind="fk")
cache.register_relationship(["order:12847", "item:555", "item:777"], kind="fk")

# runtime: a query touches the order row...
hit, level, _ = cache.access("order:12847")
print(f"access order:12847 -> hit={hit} (cold miss, as expected)")

# ...and PFCS has already prefetched everything provably related:
for key in ["customer:3291", "item:555", "item:777"]:
    hit, level, was_prefetched = cache.access(key)
    print(f"access {key:14s} -> hit={hit} at {level} "
          f"(prefetched={was_prefetched})")

print(f"\nprefetches issued: {cache.prefetches_issued} — every one "
      "mathematically related to its trigger")
print(f"factorization stage mix: {cache.factor_stats.as_dict()}")
