"""Serve a small model with batched requests through the continuous-
batching engine + PFCS paged KV cache (prefix sharing, page prefetch).

    PYTHONPATH=src python examples/serve_lm.py
"""

import sys

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    serve_main(["--arch", "qwen2.5-3b", "--requests", "12",
                "--max-new", "16", "--max-batch", "4", "--max-seq", "192",
                "--shared-prefix", "32"])
    sys.exit(0)
