"""Serve a small model with batched requests through the PFCS serving
stack (prefix sharing, table-driven page prefetch).

Two passes: a real smoke-scale model at small batch through the
``ServingEngine`` decode loop, then the null-model load-generator mode
at 128 concurrent slots through the continuous-batching ``SlotMachine``
front-end (DESIGN.md §10) — the serving hot path the load benchmarks
(`benchmarks.cases.case_serving` / `case_batching`) measure.

    PYTHONPATH=src python examples/serve_lm.py
"""

import sys

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    serve_main(["--arch", "qwen2.5-3b", "--requests", "12",
                "--max-new", "16", "--max-batch", "4", "--max-seq", "192",
                "--shared-prefix", "32"])
    serve_main(["--null-model", "--kv", "vec", "--max-batch", "128",
                "--requests", "256", "--max-new", "16",
                "--shared-prefix", "64"])
    sys.exit(0)
