"""Serve a small model with batched requests through the continuous-
batching engine + the vectorized PFCS paged KV cache (prefix sharing,
table-driven page prefetch).

Two passes: a real smoke-scale model at small batch, then the
null-model load-generator mode at 128 concurrent slots — the serving
hot path the load benchmark (`benchmarks.cases.case_serving`) measures.

    PYTHONPATH=src python examples/serve_lm.py
"""

import sys

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    serve_main(["--arch", "qwen2.5-3b", "--requests", "12",
                "--max-new", "16", "--max-batch", "4", "--max-seq", "192",
                "--shared-prefix", "32"])
    serve_main(["--null-model", "--kv", "vec", "--max-batch", "128",
                "--requests", "256", "--max-new", "16",
                "--shared-prefix", "64"])
    sys.exit(0)
