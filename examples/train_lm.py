"""End-to-end training driver: a ~100M-parameter qwen-family model on the
synthetic corpus, with checkpointing, resume, and the PFCS-cached data
tier — the full production path at example scale.

Default profile is CPU-sized (~33M params, 120 steps, a few minutes on
one core).  ``--full-100m`` runs the actual ~100M config (same code,
longer wall time).

    PYTHONPATH=src python examples/train_lm.py
    PYTHONPATH=src python examples/train_lm.py --full-100m --steps 300
"""

import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    args, extra = ap.parse_known_args()

    if args.full_100m:
        steps = args.steps or 300
        argv = ["--arch", "qwen2.5-3b", "--smoke",
                "--d-model", "768", "--n-layers", "12",
                "--steps", str(steps), "--batch", "8", "--seq", "256",
                "--lr", "6e-4", "--ckpt-every", "100"]
    else:
        steps = args.steps or 120
        argv = ["--arch", "qwen2.5-3b", "--smoke",
                "--d-model", "512", "--n-layers", "8",
                "--steps", str(steps), "--batch", "4", "--seq", "128",
                "--lr", "1e-3", "--ckpt-every", "60"]
    return train_main(argv + extra)


if __name__ == "__main__":
    sys.exit(0 if main() else 0)
